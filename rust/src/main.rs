//! `qadmm` — leader entrypoint.
//!
//! Subcommands:
//!   run        run one experiment preset (sequential simulator)
//!   fig3       regenerate Figure 3 (LASSO, accuracy vs iters/bits)
//!   fig4       regenerate Figure 4 (CNN/MNIST, test acc vs iters/bits)
//!   ablation   design-choice sweeps (q, EF, compressor family, tau, P)
//!   downlink   tau x downlink-delay sweep at n in {256, 1024} (event engine)
//!   trigger    event-trigger delta x adaptive-level sweep vs fixed QSGD
//!   serve      deployment server: wire frames over TCP / Unix sockets
//!   worker     deployment client: one node against a serve endpoint
//!   deploy-smoke  serve + worker fleet on both transports; asserts byte
//!              reconciliation, capture->replay, and convergence
//!   info       inspect the artifact manifest
//!   selftest   PJRT round-trip smoke test
//!
//! Example: `qadmm fig3 --iters 700 --trials 10 --backend hlo`

use std::path::PathBuf;

use qadmm::admm::runner::{self, ProblemFactory};
use qadmm::comm::network::FaultSpec;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, Backend, EngineKind, ProblemKind};
use qadmm::deploy::transport::Endpoint;
use qadmm::deploy::worker::{run_worker, WorkerOptions};
use qadmm::exp::{ablation, deploy, downlink, fig3, fig4, resume, topology, trigger};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::problems::nn::{NnArch, NnProblem};
use qadmm::problems::Problem;
use qadmm::runtime::artifacts::Manifest;
use qadmm::runtime::service::ComputeService;
use qadmm::runtime::tensor::Tensor;
use qadmm::runtime::Runtime;
use qadmm::util::cli::Args;
use qadmm::util::rng::Pcg64;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let cmd = args.positional().first().cloned().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "run" => cmd_run(&mut args),
        "fig3" => cmd_fig3(&mut args),
        "fig4" => cmd_fig4(&mut args),
        "ablation" => cmd_ablation(&mut args),
        "downlink" => cmd_downlink(&mut args),
        "topology" => cmd_topology(&mut args),
        "trigger" => cmd_trigger(&mut args),
        "resume" => cmd_resume(&mut args),
        "serve" => cmd_serve(&mut args),
        "worker" => cmd_worker(&mut args),
        "deploy-smoke" => cmd_deploy_smoke(&mut args),
        "info" => cmd_info(&mut args),
        "selftest" => cmd_selftest(&mut args),
        _ => {
            print!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
qadmm — Communication-Efficient Distributed Asynchronous ADMM

USAGE: qadmm <cmd> [--options]

  run       --preset NAME [--engine seq|event|threaded] [--iters N]
            [--trials N] [--q N|--compressor KIND] [--tau N] [--p N]
            [--seed N] [--no-ef] [--out DIR]
            [--compute-delay L] [--uplink-delay L] [--downlink-delay L]
            [--clock-drift E] [--refresh-every K]  (K rounds between full
            recomputes of the incremental consensus sum; 0 = never)
            [--topology star|tree:F|gossip:K] [--p-tier P_g]
            [--trigger-delta D] [--adapt-levels]  (event-triggered uplink:
             transmit only when the EF-adjusted delta has inf-norm > D —
             a skipped dispatch still counts toward P/tau but ships 0 bits;
             --adapt-levels starts QSGD coarse and refines per node as its
             realized residual shrinks; requires a qsgdQ compressor)
            [--metrics-sample K]  (evaluate the loss on a deterministic
             K-node stride instead of the full fleet, scaled back to fleet
             magnitude — observation-only, for n >> 10^4 runs; 0 = all)
            [--checkpoint-every K] [--checkpoint FILE] [--resume-from FILE]
            (periodic run snapshots; a resumed run is bit-identical to the
             uninterrupted one — seq/event engines, single trial)
            [--record-timeline FILE]   (event engine: log the realized
             (time, seq, kind) stream + per-round arrival/dispatch sets)
            [--replay-timeline FILE]   (threaded engine: replay a recorded
             schedule instead of wall-clock sleeps; star topology)
  fig3      [--iters N] [--trials N] [--backend hlo|native] [--target X]
  fig4      [--iters N] [--trials N] [--arch cnn|mlp] [--train N] [--test N]
  ablation  [--iters N] [--trials N] [--target X]
  downlink  [--iters N] [--trials N] [--target X] [--quick]
  topology  [--iters N] [--trials N] [--target X] [--quick]
            (star vs tree vs gossip convergence-per-bit, event engine)
  trigger   [--iters N] [--trials N] [--target X] [--quick]
            (event-trigger dead-band delta x adaptive level schedule vs
             fixed QSGD on bits-to-target; LASSO + logreg families)
  resume    [--iters N] [--k K] [--out DIR] [--quick]
            (checkpoint/resume parity smoke: every engine x topology cell
             checkpoints at round K, resumes, and diffs the continued run
             bit-for-bit against a straight run; also records a timeline
             and replays it through the threaded bridge)
  serve     --preset NAME [--listen EP] [--nodes N] [--iters N]
            [--idle-timeout SECS] [--record-timeline FILE] [--loadgen N]
            [--io-threads K]
            (socket deployment server: a sharded poll(2) reactor — K I/O
             threads (default min(cores, 8)) own all connections, so the
             server runs K+1 threads total regardless of fleet size; binds
             EP, drives the fold loop over real connections, reconciles
             socket bytes against eq. 20 bits exactly;
             --loadgen N runs N in-process workers against the socket and
             reports rounds/s, io threads, per-link B/s, p50/p99 round
             latency — N in {64, 256, 512} is the bench sweep shape;
             the old threaded in-process deployment is `run --engine threaded`)
  worker    --connect EP --node I [--preset NAME] [--nodes N]
            [--idle-timeout SECS]
            (deployment client for node I; config must digest-match the
             server's or the handshake is rejected)
  deploy-smoke  [--nodes N] [--iters N] [--target X] [--threads]
            (serve + N workers on UDS then TCP-localhost; asserts exact
             byte reconciliation, capture->replay arrival equality, and
             convergence; --threads uses in-process workers instead of
             `qadmm worker` child processes)
  info      [--artifacts DIR]
  selftest  [--artifacts DIR]

Presets: fig3 fig3-tau1 fig4 fig4-full ci-lasso e2e-mlp
Compressors: identity | qsgdQ | sign | topkP | randkP (P in permille, 1..=1000)
Engines: seq (lockstep simulator) | event (virtual-time, 1000+ nodes)
         | threaded (real threads + injected latency)
Latency models L: none | const:S | exp:MEAN | mix:FAST,SLOW,P_SLOW
  (per-link legs; odd-indexed nodes are 4x slower, --clock-drift E in [0,1)
   spreads node clock rates over [1-E, 1+E])
Endpoints EP: tcp:HOST:PORT (port 0 = kernel-assigned) | uds:/path/to.sock
Topologies: star (direct fan-in) | tree:F (2-tier, fanout-F aggregators)
            | gossip:K (random relay among K aggregators); --p-tier sets the
            per-aggregator arrival threshold P_g before a re-quantized
            partial-sum forward
";

fn apply_overrides(
    cfg: &mut qadmm::ExperimentConfig,
    args: &mut Args,
) -> anyhow::Result<()> {
    cfg.iters = args.usize("iters", cfg.iters);
    cfg.mc_trials = args.usize("trials", cfg.mc_trials);
    // fleet size (problem node count); deploy endpoints must agree on it
    if let Some(nodes) = args.str_opt("nodes") {
        let nodes: usize = nodes.parse().map_err(|_| anyhow::anyhow!("--nodes wants a count"))?;
        anyhow::ensure!(nodes > 0, "--nodes must be positive");
        match &mut cfg.problem {
            ProblemKind::Lasso { n, .. }
            | ProblemKind::Mlp { n, .. }
            | ProblemKind::Cnn { n, .. } => *n = nodes,
        }
    }
    cfg.tau = args.usize("tau", cfg.tau);
    cfg.p_min = args.usize("p", cfg.p_min);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.eval_every = args.usize("eval-every", cfg.eval_every);
    cfg.metrics_sample = args.usize("metrics-sample", cfg.metrics_sample);
    cfg.consensus_refresh_every =
        args.usize("refresh-every", cfg.consensus_refresh_every);
    let engine = args.choice(
        "engine",
        cfg.engine.label(),
        &["seq", "sequential", "sim", "event", "virtual", "threaded", "threads"],
    )?;
    cfg.engine = EngineKind::parse(&engine)?;
    if let Some(c) = args.str_opt("compressor") {
        cfg.compressor = CompressorKind::parse(&c)?;
    } else {
        let q = args.usize("q", 0);
        if q > 0 {
            cfg.compressor = CompressorKind::Qsgd { bits: q as u8 };
        }
    }
    if args.flag("no-ef") {
        cfg.error_feedback = false;
    }
    // per-link latency decomposition (engine=event virtual delays,
    // engine=threaded injected sleeps)
    if let Some(l) = args.str_opt("compute-delay") {
        cfg.link.compute = qadmm::comm::latency::LatencyModel::parse(&l)?;
    }
    if let Some(l) = args.str_opt("uplink-delay") {
        cfg.link.uplink = qadmm::comm::latency::LatencyModel::parse(&l)?;
    }
    if let Some(l) = args.str_opt("downlink-delay") {
        cfg.link.downlink = qadmm::comm::latency::LatencyModel::parse(&l)?;
    }
    cfg.link.clock_drift = args.f64("clock-drift", cfg.link.clock_drift);
    // aggregation topology (consensus fan-in) + per-tier threshold
    if let Some(t) = args.str_opt("topology") {
        cfg.topology = qadmm::topology::TopologyKind::parse(&t)?;
    }
    cfg.p_tier = args.usize("p-tier", cfg.p_tier);
    // event-triggered transmission + adaptive level schedule
    cfg.trigger.delta = args.f64("trigger-delta", cfg.trigger.delta);
    if args.flag("adapt-levels") {
        cfg.trigger.adapt = true;
    }
    // problem-level overrides
    let rho_override = args.f64("rho", f64::NAN);
    let lr_override = args.f64("lr", f64::NAN);
    match &mut cfg.problem {
        ProblemKind::Lasso { rho, .. } => {
            if rho_override.is_finite() {
                *rho = rho_override;
            }
        }
        ProblemKind::Mlp { rho, lr, .. } | ProblemKind::Cnn { rho, lr, .. } => {
            if rho_override.is_finite() {
                *rho = rho_override;
            }
            if lr_override.is_finite() {
                *lr = lr_override;
            }
        }
    }
    if let Some(b) = args.str_opt("backend") {
        cfg.backend = match b.as_str() {
            "hlo" => Backend::Hlo,
            "native" => Backend::Native,
            other => anyhow::bail!("unknown backend '{other}'"),
        };
    }
    Ok(())
}

/// Build a problem factory for any preset (shared by run/serve).
fn make_factory<'a>(
    cfg: &qadmm::ExperimentConfig,
    service: Option<&'a ComputeService>,
    manifest: Option<&'a Manifest>,
    artifact_consts: (usize, usize),
    data_dir: PathBuf,
    n_train: usize,
    n_test: usize,
) -> Box<ProblemFactory<'a>> {
    let cfg = cfg.clone();
    let (art_m, art_n) = artifact_consts;
    Box::new(move |seed: u64, data_rng: &mut Pcg64| -> anyhow::Result<Box<dyn Problem>> {
        match cfg.problem {
            ProblemKind::Lasso { m, h, n, rho, theta } => {
                let mut p =
                    LassoProblem::generate(LassoConfig { m, h, n, rho, theta }, data_rng)?;
                if cfg.backend == Backend::Hlo {
                    let svc = service.expect("HLO backend needs the compute service");
                    p = p.with_hlo(Box::new(svc.client()), art_m, art_n)?;
                }
                Ok(Box::new(p))
            }
            ProblemKind::Mlp { n, rho, lr } | ProblemKind::Cnn { n, rho, lr } => {
                let arch = if matches!(cfg.problem, ProblemKind::Mlp { .. }) {
                    NnArch::Mlp
                } else {
                    NnArch::Cnn
                };
                let p = NnProblem::new(
                    arch,
                    n,
                    rho,
                    lr,
                    Box::new(service.expect("NN needs the compute service").client()),
                    manifest.expect("NN needs the manifest"),
                    n_train,
                    n_test,
                    &data_dir,
                    seed,
                )?;
                Ok(Box::new(p))
            }
        }
    })
}

fn needed_artifacts(cfg: &qadmm::ExperimentConfig) -> Vec<String> {
    match cfg.problem {
        ProblemKind::Lasso { .. } => vec!["lasso_node_step".into()],
        ProblemKind::Mlp { .. } => vec!["mlp_local_update".into(), "mlp_eval".into()],
        ProblemKind::Cnn { .. } => vec!["cnn_local_update".into(), "cnn_eval".into()],
    }
}

fn cmd_run(args: &mut Args) -> anyhow::Result<()> {
    let preset = args.str("preset", "ci-lasso");
    let mut cfg = presets::by_name(&preset)?;
    apply_overrides(&mut cfg, args)?;
    let out_dir = PathBuf::from(args.str("out", "out"));
    let artifact_dir = PathBuf::from(args.str("artifacts", "artifacts"));
    let data_dir = PathBuf::from(args.str("data", "data/mnist"));
    let n_train = args.usize("train", 3000);
    let n_test = args.usize("test", 1024);
    // snapshot / replay plumbing (see the snapshot module docs)
    let mut single_opts = runner::SingleRunOptions {
        checkpoint_every: args.usize("checkpoint-every", 0),
        checkpoint_path: args.str_opt("checkpoint").map(PathBuf::from),
        resume_from: args.str_opt("resume-from").map(PathBuf::from),
        record_timeline: args.str_opt("record-timeline").map(PathBuf::from),
    };
    if single_opts.checkpoint_every > 0 && single_opts.checkpoint_path.is_none() {
        // keep every artifact of a run under its --out directory
        single_opts.checkpoint_path = Some(out_dir.join(format!("{}.qsnap", cfg.name)));
    }
    let replay_timeline = args.str_opt("replay-timeline").map(PathBuf::from);
    args.finish()?;
    cfg.validate()?;
    if replay_timeline.is_some() {
        anyhow::ensure!(
            cfg.engine == EngineKind::Threaded,
            "--replay-timeline drives the threaded runtime (use --engine threaded)"
        );
    }
    if single_opts.is_active() {
        anyhow::ensure!(
            cfg.engine != EngineKind::Threaded,
            "checkpoint/record options drive the in-process engines; the threaded \
             runtime replays recordings (--replay-timeline)"
        );
    }

    let needs_hlo = cfg.backend == Backend::Hlo
        || matches!(cfg.problem, ProblemKind::Mlp { .. } | ProblemKind::Cnn { .. });
    let service = if needs_hlo {
        Some(ComputeService::start(artifact_dir.clone(), needed_artifacts(&cfg))?)
    } else {
        None
    };
    let manifest = if needs_hlo {
        Some(Manifest::load(&artifact_dir.join("manifest.json"))?)
    } else {
        None
    };
    let art_consts = manifest
        .as_ref()
        .map(|m| {
            (m.const_usize("lasso_m").unwrap_or(0), m.const_usize("lasso_n").unwrap_or(0))
        })
        .unwrap_or((0, 0));

    // The threaded deployment drives one real server/node topology; it has
    // no Monte-Carlo averaging, so don't claim --trials it won't run.
    let trials = if cfg.engine == EngineKind::Threaded { 1 } else { cfg.mc_trials };
    println!(
        "running {} on engine={} ({} iters x {} trials)...",
        cfg.name,
        cfg.engine.label(),
        cfg.iters,
        trials
    );
    if cfg.engine == EngineKind::Threaded && cfg.mc_trials > 1 {
        println!("note: engine=threaded runs a single deployment; --trials ignored");
    }
    let mut factory = make_factory(
        &cfg,
        service.as_ref(),
        manifest.as_ref(),
        art_consts,
        data_dir,
        n_train,
        n_test,
    );
    if cfg.engine == EngineKind::Threaded {
        // One problem instance, seeded like trial 0 of the in-process
        // engines so threaded results are comparable at equal seed.
        let seed = runner::trial_seed(cfg.seed, 0);
        let mut rngs = qadmm::admm::sim::TrialRngs::new(seed);
        let boxed = factory(seed, &mut rngs.data)?;
        drop(factory);
        let problem: Box<dyn Problem + Send> = unsafe { make_send(boxed) };
        let outcome = match &replay_timeline {
            Some(path) => {
                let tl = qadmm::snapshot::timeline::RecordedTimeline::load(path)?;
                println!(
                    "replaying {} recorded rounds from {} (no injected sleeps)",
                    tl.rounds.len(),
                    path.display()
                );
                qadmm::coordinator::run_threaded_replay(
                    &cfg,
                    problem,
                    FaultSpec::default(),
                    &tl,
                )?
            }
            None => qadmm::coordinator::run_threaded(&cfg, problem, FaultSpec::default())?,
        };
        std::fs::create_dir_all(&out_dir)?;
        let csv = out_dir.join(format!("{}.csv", cfg.name));
        outcome.recorder.write_csv(&csv)?;
        if let Some(last) = outcome.recorder.last() {
            println!(
                "final: iter={} accuracy={:.3e} test_acc={:.4} loss={:.4e} bits/param={:.1}",
                last.iter, last.accuracy, last.test_acc, last.loss, outcome.normalized_bits
            );
        }
        println!("wrote {}", csv.display());
        return Ok(());
    }
    if single_opts.is_active() {
        // Checkpoint/resume/recording is single-trial by construction: a
        // snapshot is ONE run's state (resume MC sweeps trial by trial).
        if cfg.mc_trials > 1 {
            println!("note: checkpoint/record runs a single trial; --trials ignored");
            cfg.mc_trials = 1;
        }
        let rec = runner::run_single(&cfg, factory.as_mut(), &single_opts)?;
        drop(factory);
        std::fs::create_dir_all(&out_dir)?;
        let csv = out_dir.join(format!("{}.csv", cfg.name));
        rec.write_csv(&csv)?;
        std::fs::write(
            out_dir.join(format!("{}.config.json", cfg.name)),
            cfg.to_json().to_string_pretty(),
        )?;
        if let Some(last) = rec.last() {
            println!(
                "final: iter={} accuracy={:.3e} test_acc={:.4} loss={:.4e} bits/param={:.1}",
                last.iter, last.accuracy, last.test_acc, last.loss, last.comm_bits
            );
        }
        println!("wrote {}", csv.display());
        return Ok(());
    }
    let res = runner::run_mc(&cfg, factory.as_mut())?;
    drop(factory);
    let rec = res.mean_recorder();
    std::fs::create_dir_all(&out_dir)?;
    let csv = out_dir.join(format!("{}.csv", cfg.name));
    rec.write_csv(&csv)?;
    std::fs::write(
        out_dir.join(format!("{}.config.json", cfg.name)),
        cfg.to_json().to_string_pretty(),
    )?;
    if let Some(last) = rec.last() {
        println!(
            "final: iter={} accuracy={:.3e} test_acc={:.4} loss={:.4e} bits/param={:.1}",
            last.iter, last.accuracy, last.test_acc, last.loss, last.comm_bits
        );
    }
    println!("wrote {}", csv.display());
    Ok(())
}

fn cmd_fig3(args: &mut Args) -> anyhow::Result<()> {
    let mut opts = fig3::Fig3Options {
        iters: args.usize("iters", presets::fig3(3).iters),
        mc_trials: args.usize("trials", presets::fig3(3).mc_trials),
        target: args.f64("target", 1e-10),
        out_dir: PathBuf::from(args.str("out", "out")),
        artifact_dir: PathBuf::from(args.str("artifacts", "artifacts")),
        ..Default::default()
    };
    if args.str("backend", "hlo") == "native" {
        opts.backend = Backend::Native;
    }
    args.finish()?;
    let summary = fig3::run(&opts)?;
    for s in &summary.series {
        println!("--- fig3 series {} ---", s.label);
        print!(
            "{}",
            qadmm::exp::milestones(&s.mean_recorder(), |r| r.accuracy)
        );
    }
    for h in &summary.headline {
        println!("{h}");
    }
    Ok(())
}

fn cmd_fig4(args: &mut Args) -> anyhow::Result<()> {
    let arch = match args.str("arch", "cnn").as_str() {
        "cnn" => NnArch::Cnn,
        "mlp" => NnArch::Mlp,
        other => anyhow::bail!("unknown arch '{other}'"),
    };
    let opts = fig4::Fig4Options {
        arch,
        iters: args.usize("iters", presets::fig4().iters),
        mc_trials: args.usize("trials", presets::fig4().mc_trials),
        n_train: args.usize("train", 3000),
        n_test: args.usize("test", 1024),
        target: args.f64("target", 0.95),
        out_dir: PathBuf::from(args.str("out", "out")),
        artifact_dir: PathBuf::from(args.str("artifacts", "artifacts")),
        data_dir: PathBuf::from(args.str("data", "data/mnist")),
    };
    args.finish()?;
    let summary = fig4::run(&opts)?;
    for s in &summary.series {
        println!("--- fig4 series {} ---", s.label);
        print!("{}", qadmm::exp::milestones(&s.mean_recorder(), |r| r.test_acc));
    }
    for h in &summary.headline {
        println!("{h}");
    }
    Ok(())
}

fn cmd_ablation(args: &mut Args) -> anyhow::Result<()> {
    let opts = ablation::AblationOptions {
        iters: args.usize("iters", 400),
        mc_trials: args.usize("trials", 3),
        target: args.f64("target", 1e-8),
    };
    args.finish()?;
    ablation::run_all(&opts)?;
    Ok(())
}

fn cmd_downlink(args: &mut Args) -> anyhow::Result<()> {
    let defaults = downlink::DownlinkSweepOptions::default();
    let opts = downlink::DownlinkSweepOptions {
        iters: args.usize("iters", defaults.iters),
        mc_trials: args.usize("trials", defaults.mc_trials),
        target: args.f64("target", defaults.target),
        quick: args.flag("quick"),
    };
    args.finish()?;
    downlink::run(&opts)?;
    Ok(())
}

fn cmd_topology(args: &mut Args) -> anyhow::Result<()> {
    let defaults = topology::TopologySweepOptions::default();
    let opts = topology::TopologySweepOptions {
        iters: args.usize("iters", defaults.iters),
        mc_trials: args.usize("trials", defaults.mc_trials),
        target: args.f64("target", defaults.target),
        quick: args.flag("quick"),
    };
    args.finish()?;
    topology::run(&opts)?;
    Ok(())
}

fn cmd_trigger(args: &mut Args) -> anyhow::Result<()> {
    let defaults = trigger::TriggerSweepOptions::default();
    let opts = trigger::TriggerSweepOptions {
        iters: args.usize("iters", defaults.iters),
        mc_trials: args.usize("trials", defaults.mc_trials),
        target: args.f64("target", defaults.target),
        quick: args.flag("quick"),
    };
    args.finish()?;
    trigger::run(&opts)?;
    Ok(())
}

fn cmd_resume(args: &mut Args) -> anyhow::Result<()> {
    let defaults = resume::ResumeSmokeOptions::default();
    let opts = resume::ResumeSmokeOptions {
        iters: args.usize("iters", defaults.iters),
        k: args.usize("k", defaults.k),
        out_dir: PathBuf::from(args.str("out", "out")),
        quick: args.flag("quick"),
    };
    args.finish()?;
    resume::run(&opts)
}

fn cmd_serve(args: &mut Args) -> anyhow::Result<()> {
    let preset = args.str("preset", "ci-lasso");
    let mut cfg = presets::by_name(&preset)?;
    apply_overrides(&mut cfg, args)?;
    let listen = Endpoint::parse(&args.str("listen", "tcp:127.0.0.1:7077"))?;
    let loadgen = args.usize("loadgen", 0);
    let idle = args.f64("idle-timeout", 30.0);
    let io_threads = args.usize("io-threads", 0);
    let record = args.str_opt("record-timeline").map(PathBuf::from);
    args.finish()?;
    if loadgen > 0 {
        // the loadgen fleet *is* the deployment: size the problem to it
        match &mut cfg.problem {
            ProblemKind::Lasso { n, .. }
            | ProblemKind::Mlp { n, .. }
            | ProblemKind::Cnn { n, .. } => *n = loadgen,
        }
    }
    cfg.validate()?;
    let n = cfg.problem.n_nodes();
    let opts = qadmm::deploy::server::ServeOptions {
        idle_timeout: std::time::Duration::from_secs_f64(idle),
    };
    let reactor = qadmm::deploy::server::ReactorOptions {
        io_threads: if io_threads > 0 { Some(io_threads) } else { None },
        ..Default::default()
    };
    let report = if loadgen > 0 {
        println!("serving {} on {} with {loadgen} loadgen workers...", cfg.name, listen.label());
        deploy::serve_with_threads_tuned(&cfg, &listen, loadgen, &opts, &reactor)?
    } else {
        println!("serving {} for {n} external workers...", cfg.name);
        qadmm::deploy::server::serve_tuned(
            &cfg,
            deploy::make_native_problem(&cfg)?,
            &listen,
            &opts,
            &reactor,
            |ep| {
                println!("listening on {}", ep.label());
                Ok(())
            },
        )?
    };
    qadmm::deploy::reconcile(&report.books, &report.accounting)?;
    let rounds = report.timeline.rounds.len();
    println!(
        "done: {rounds} rounds in {:.2}s ({:.1} rounds/s) on {} io threads, \
         byte books reconciled",
        report.wall_s,
        rounds as f64 / report.wall_s.max(1e-9),
        report.io_threads
    );
    let times: Vec<f64> = report.timeline.rounds.iter().map(|r| r.time).collect();
    if let Some((p50, p99)) = deploy::round_latency_stats(&times) {
        println!("round latency: p50 {:.1}us p99 {:.1}us", p50 * 1e6, p99 * 1e6);
    }
    for (i, b) in report.books.iter().enumerate() {
        println!(
            "  link {i}: {} B up ({:.0} B/s), {} B down ({:.0} B/s)",
            b.up_total,
            b.up_total as f64 / report.wall_s.max(1e-9),
            b.down_total,
            b.down_total as f64 / report.wall_s.max(1e-9)
        );
    }
    if let Some(last) = report.recorder.records.last() {
        // deploy serves native LASSO only (make_native_problem enforces it)
        let ProblemKind::Lasso { m, .. } = cfg.problem else { unreachable!() };
        println!(
            "final: iter={} accuracy={:.3e} loss={:.4e} bits/param={:.1}",
            last.iter,
            last.accuracy,
            last.loss,
            report.accounting.normalized_bits(m)
        );
    }
    if let Some(path) = record {
        std::fs::write(&path, report.timeline.to_json().to_string_pretty())?;
        println!("wrote timeline to {} (replayable offline)", path.display());
    }
    Ok(())
}

fn cmd_worker(args: &mut Args) -> anyhow::Result<()> {
    let preset = args.str("preset", "ci-lasso");
    let mut cfg = presets::by_name(&preset)?;
    apply_overrides(&mut cfg, args)?;
    let connect = Endpoint::parse(
        &args.str_opt("connect").ok_or_else(|| anyhow::anyhow!("--connect is required"))?,
    )?;
    let node = args.usize("node", usize::MAX);
    anyhow::ensure!(node != usize::MAX, "--node is required");
    let idle = args.f64("idle-timeout", 60.0);
    args.finish()?;
    let mut opts = WorkerOptions::new(node);
    opts.idle_timeout = std::time::Duration::from_secs_f64(idle);
    let problem = deploy::make_native_problem(&cfg)?;
    let report = run_worker(&cfg, problem, &connect, &opts)?;
    println!(
        "worker {node}: {} updates + {} skips over {} rounds, {} B up / {} B down{}",
        report.updates_sent,
        report.skips_sent,
        report.rounds_applied,
        report.bytes_up,
        report.bytes_down,
        if report.acked_shutdown { ", drained cleanly" } else { "" }
    );
    Ok(())
}

fn cmd_deploy_smoke(args: &mut Args) -> anyhow::Result<()> {
    let defaults = deploy::DeploySmokeOptions::default();
    let opts = deploy::DeploySmokeOptions {
        nodes: args.usize("nodes", defaults.nodes),
        iters: args.usize("iters", defaults.iters),
        target: args.f64("target", defaults.target),
        worker_exe: if args.flag("threads") {
            None
        } else {
            Some(std::env::current_exe()?)
        },
    };
    args.finish()?;
    deploy::run(&opts)
}

/// The factory returns `Box<dyn Problem>`; when every exec handle inside is
/// a `ComputeClient` (channel sender) the value is Send in fact. This
/// re-brands the box for the threaded runtime.
unsafe fn make_send(p: Box<dyn Problem>) -> Box<dyn Problem + Send> {
    unsafe { std::mem::transmute(p) }
}

fn cmd_info(args: &mut Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.str("artifacts", "artifacts"));
    args.finish()?;
    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    println!("artifacts in {}:", dir.display());
    for (name, spec) in &manifest.artifacts {
        let ins: Vec<String> = spec
            .inputs
            .iter()
            .map(|i| format!("{}:{}{:?}", i.name, i.dtype, i.shape))
            .collect();
        println!("  {name:28} {} -> {:?}", ins.join(" "), spec.outputs);
    }
    for (k, v) in &manifest.consts {
        println!("  const {k} = {v}");
    }
    Ok(())
}

fn cmd_selftest(args: &mut Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.str("artifacts", "artifacts"));
    args.finish()?;
    let rt = Runtime::open(&dir)?;
    // run the standalone quantizer artifact and check against native qsgd
    let m = 200;
    let mut rng = Pcg64::seed_from_u64(1);
    let delta = rng.normal_vec(m, 0.0, 1.0);
    let noise = rng.uniform_vec_f64(m);
    let out = rt.call(
        "quantize_f64_m200",
        &[
            Tensor::vec_f64(delta.clone()),
            Tensor::vec_f64(noise.clone()),
            Tensor::scalar_f64(3.0),
        ],
    )?;
    let q = qadmm::compress::qsgd::Qsgd::new(3);
    let (levels, norm) = q.quantize_with_noise(&delta, &noise);
    anyhow::ensure!(out[1].as_i32()? == levels.as_slice(), "level mismatch HLO vs native");
    anyhow::ensure!((out[2].scalar()? - norm).abs() < 1e-15, "norm mismatch");
    println!("selftest OK: HLO quantizer == native quantizer ({m} elements)");
    Ok(())
}
