//! The parity contract between the sequential simulator and the
//! event-driven virtual-time engine: with **zero delay on every link leg**
//! (compute, uplink *and* downlink) and the **identity compressor**, every
//! dispatched update — and every ẑ broadcast — arrives in the same virtual
//! instant, so engine rounds coincide exactly with simulator iterations —
//! the `z` trajectory, the per-round metric records and the cumulative
//! comm-bit accounting must be *bit-identical*, for both the exact-update
//! (LASSO) and inexact-update (logistic regression) problem families and
//! across (τ, P, oracle) variations. A nonzero downlink leg must *break*
//! the collapse: nodes then compute against stale ẑ mirrors and the
//! trajectory measurably changes.

use qadmm::admm::engine::EventEngine;
use qadmm::admm::sim::{AsyncSim, TrialRngs};
use qadmm::comm::latency::LatencyModel;
use qadmm::comm::profile::LinkConfig;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, ExperimentConfig, OracleConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::problems::logreg::{LogRegConfig, LogRegProblem};
use qadmm::problems::Problem;
use qadmm::util::rng::Pcg64;

fn parity_cfg(n: usize, tau: usize, p_min: usize, regroup: bool) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    cfg.name = format!("parity-tau{tau}-p{p_min}");
    cfg.problem = ProblemKind::Lasso { m: 24, h: 18, n, rho: 30.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Identity; // zero quantizer randomness
    cfg.tau = tau;
    cfg.p_min = p_min;
    cfg.iters = 40;
    cfg.mc_trials = 1;
    cfg.eval_every = 1;
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: regroup };
    cfg.link = LinkConfig::none(); // zero delay on every leg
    cfg
}

/// Drive both engines in lockstep on identically-generated problems and
/// assert bit-identical state after every round.
fn assert_parity(
    cfg: &ExperimentConfig,
    make: &dyn Fn(&mut Pcg64) -> Box<dyn Problem>,
) {
    let mut rngs_a = TrialRngs::new(cfg.seed);
    let mut prob_a = make(&mut rngs_a.data);
    let mut sim = AsyncSim::new(cfg, prob_a.as_mut(), rngs_a).unwrap();

    let mut rngs_b = TrialRngs::new(cfg.seed);
    let mut prob_b = make(&mut rngs_b.data);
    let mut eng = EventEngine::new(cfg, prob_b.as_mut(), rngs_b).unwrap();

    // Algorithm 1 lines 1–9 charge the same full-precision exchange.
    assert_eq!(
        sim.accounting().total_bits(),
        eng.accounting().total_bits(),
        "init accounting diverged"
    );
    // Before any round fires, stats must not leak a sentinel.
    assert_eq!(eng.stats().min_arrivals, None);

    for r in 1..=cfg.iters {
        sim.step().unwrap();
        eng.step_round().unwrap();
        assert_eq!(sim.z(), eng.z(), "z trajectory diverged at round {r} ({})", cfg.name);
        assert_eq!(
            sim.accounting().total_bits(),
            eng.accounting().total_bits(),
            "comm bits diverged at round {r} ({})",
            cfg.name
        );
        assert_eq!(sim.staleness(), eng.staleness(), "staleness diverged at round {r}");
    }

    // With zero latency the engine's timeline never leaves t = 0.
    assert_eq!(eng.virtual_time(), 0.0);
    let stats = eng.stats();
    assert_eq!(stats.rounds, cfg.iters);
    assert!(stats.min_arrivals.expect("rounds fired") >= cfg.p_min);
    assert!(stats.max_staleness + 1 <= cfg.tau.max(1));

    // The engines must have dead-banded exactly the same dispatches (0 on
    // both sides whenever the trigger is disabled).
    assert_eq!(
        sim.trigger().skipped(),
        eng.trigger().skipped(),
        "skip counts diverged ({})",
        cfg.name
    );

    // Full metric series, NaN-safe (test_acc is NaN for convex problems).
    let (a, b) = (sim.recorder(), eng.recorder());
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.iter, rb.iter);
        assert_eq!(ra.active_nodes, rb.active_nodes);
        assert_eq!(ra.comm_bits.to_bits(), rb.comm_bits.to_bits());
        assert_eq!(ra.accuracy.to_bits(), rb.accuracy.to_bits());
        assert_eq!(ra.test_acc.to_bits(), rb.test_acc.to_bits());
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
    }
}

#[test]
fn lasso_trajectories_are_bit_identical() {
    for (tau, p_min, regroup) in [(3usize, 1usize, false), (4, 2, true), (1, 4, false)] {
        let cfg = parity_cfg(4, tau, p_min, regroup);
        let lcfg = match cfg.problem {
            ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
            _ => unreachable!(),
        };
        let make = move |rng: &mut Pcg64| -> Box<dyn Problem> {
            Box::new(LassoProblem::generate(lcfg, rng).unwrap())
        };
        assert_parity(&cfg, &make);
    }
}

#[test]
fn logreg_trajectories_are_bit_identical() {
    // inexact updates (K gradient steps) through the batch fan-out path
    let lcfg = LogRegConfig { m: 10, h: 40, n: 5, rho: 2.0, gamma: 1.0, k_steps: 8, lr: 0.02 };
    let make = move |rng: &mut Pcg64| -> Box<dyn Problem> {
        Box::new(LogRegProblem::generate(lcfg, rng).unwrap())
    };
    for (tau, p_min) in [(3usize, 2usize), (2, 1)] {
        let mut cfg = parity_cfg(5, tau, p_min, false);
        cfg.name = format!("parity-logreg-tau{tau}-p{p_min}");
        cfg.eval_every = 5; // logreg eval (F* reference) is the pricey part
        assert_parity(&cfg, &make);
    }
}

/// Event-trigger parity: with the identity compressor and zero delays the
/// two engines see identical EF-adjusted deltas, so a dead-band δ > 0 must
/// suppress *exactly* the same dispatches in both — trajectory, accounting,
/// staleness and skip counts all stay bit-identical. The grid spans a δ
/// below the realized delta scale (nothing skips), one inside it (a
/// realized mix of sends and skips), and one no finite delta passes
/// (everything skips; rounds keep firing on τ−1 force-waits alone).
/// The δ = 0 + fixed-levels cell is every *other* test in this file: the
/// default `TriggerConfig` is the byte-for-byte legacy path.
#[test]
fn dead_band_trajectories_are_bit_identical_across_engines() {
    for delta in [1e-12, 1e-3, 1e300] {
        let mut cfg = parity_cfg(4, 3, 1, false);
        cfg.name = format!("parity-trigger-d{delta:.0e}");
        cfg.trigger.delta = delta;
        let lcfg = match cfg.problem {
            ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
            _ => unreachable!(),
        };
        let make = move |rng: &mut Pcg64| -> Box<dyn Problem> {
            Box::new(LassoProblem::generate(lcfg, rng).unwrap())
        };
        assert_parity(&cfg, &make);
    }
}

/// The incremental consensus path must stay bit-exact between engines at
/// every refresh cadence: both fold arrivals in the same order and rebuild
/// the sum from the banks on the same rounds, so parity holds whether the
/// accumulator refreshes every round, rarely, or never. (Different
/// cadences produce *different* trajectories from each other — the
/// incremental and recomputed sums differ in the last ulp — but each
/// cadence's two engines must agree exactly.)
#[test]
fn parity_holds_across_consensus_refresh_cadences() {
    for refresh in [0usize, 1, 3, 64] {
        let mut cfg = parity_cfg(4, 3, 1, false);
        cfg.name = format!("parity-refresh{refresh}");
        cfg.consensus_refresh_every = refresh;
        let lcfg = match cfg.problem {
            ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
            _ => unreachable!(),
        };
        let make = move |rng: &mut Pcg64| -> Box<dyn Problem> {
            Box::new(LassoProblem::generate(lcfg, rng).unwrap())
        };
        assert_parity(&cfg, &make);
    }
}

/// Pure clock drift cannot break parity: drift scales compute *durations*,
/// and 0.3 × 0.0 is still 0.0 — the zero-delay timeline (downlink
/// included) must stay bit-identical to the simulator even with maximally
/// skewed node clocks.
#[test]
fn zero_delay_parity_survives_clock_drift() {
    let mut cfg = parity_cfg(4, 3, 1, false);
    cfg.name = "parity-drift".into();
    cfg.link = LinkConfig { clock_drift: 0.3, ..LinkConfig::none() };
    let lcfg = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let make = move |rng: &mut Pcg64| -> Box<dyn Problem> {
        Box::new(LassoProblem::generate(lcfg, rng).unwrap())
    };
    assert_parity(&cfg, &make);
}

/// The other half of the contract: a nonzero downlink leg must *change*
/// the z-trajectory. With heterogeneous Const downlink delays (odd nodes
/// 4× slower) the broadcast reaches even nodes first; with P = 1 the
/// server fires on partial batches that the zero-downlink run never sees,
/// so the consensus inputs — and hence z — diverge, while every
/// scheduling invariant still holds.
#[test]
fn nonzero_downlink_delay_changes_the_z_trajectory() {
    let cfg_zero = parity_cfg(4, 3, 1, false);
    let mut cfg_down = parity_cfg(4, 3, 1, false);
    cfg_down.name = "parity-downlink".into();
    cfg_down.link = LinkConfig {
        compute: LatencyModel::None,
        uplink: LatencyModel::None,
        downlink: LatencyModel::Const(0.05),
        clock_drift: 0.0,
    };
    let lcfg = match cfg_zero.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let run = |cfg: &ExperimentConfig| {
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        let mut eng = EventEngine::new(cfg, &mut p, rngs).unwrap();
        let mut zs = Vec::new();
        for _ in 0..cfg.iters {
            eng.step_round().unwrap();
            zs.push(eng.z().to_vec());
            let max_d = eng.staleness().iter().copied().max().unwrap();
            assert!(max_d + 1 <= cfg.tau, "staleness bound broken under downlink delay");
        }
        (zs, eng.virtual_time(), eng.stats())
    };
    let (z_zero, t_zero, _) = run(&cfg_zero);
    let (z_down, t_down, stats_down) = run(&cfg_down);
    assert_eq!(t_zero, 0.0);
    assert!(t_down > 0.0, "downlink delay must advance virtual time");
    assert!(stats_down.min_arrivals.expect("rounds fired") >= cfg_down.p_min);
    // Same number of rounds, different trajectory: at least one round's z
    // must differ (in fact they diverge early and stay diverged).
    assert_eq!(z_zero.len(), z_down.len());
    assert!(
        z_zero.iter().zip(&z_down).any(|(a, b)| a != b),
        "delayed downlink left the z-trajectory bit-identical"
    );
}

/// Regression for the O(n)-per-virtual-instant trigger scan: with Exp
/// compute/uplink delays every arrival lands in its own virtual instant,
/// so a round at P = n/2 checks the trigger ~n/2 times — the old staleness
/// scan made that O(n²) per round. The maintained overdue counter makes
/// each check O(1); this run at n = 4096 with single-event batches must
/// finish comfortably within the wall bound while upholding every
/// scheduling invariant.
#[test]
fn fragmented_arrivals_at_4096_nodes_stay_fast() {
    let n = 4096;
    let mut cfg = presets::ci_lasso();
    cfg.name = "trigger-scan-4096".into();
    cfg.problem = ProblemKind::Lasso { m: 4, h: 2, n, rho: 20.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Identity;
    cfg.tau = 4;
    cfg.p_min = n / 2;
    cfg.iters = 3;
    cfg.mc_trials = 1;
    cfg.eval_every = cfg.iters;
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(0.01),
        uplink: LatencyModel::Exp(0.01),
        downlink: LatencyModel::None,
        clock_drift: 0.0,
    };
    let lcfg = LassoConfig { m: 4, h: 2, n, rho: 20.0, theta: 0.1 };
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
    p.set_reference_optimum(1.0); // metric value irrelevant here
    let start = std::time::Instant::now();
    let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
    for _ in 0..cfg.iters {
        eng.step_round().unwrap();
        let max_d = eng.staleness().iter().copied().max().unwrap();
        assert!(max_d + 1 <= cfg.tau, "staleness bound broken");
    }
    let stats = eng.stats();
    assert_eq!(stats.rounds, cfg.iters);
    assert!(stats.min_arrivals.expect("rounds fired") >= cfg.p_min);
    // generous even for debug builds — the old O(n²) scan is what this
    // bound guards against regressing toward
    assert!(
        start.elapsed() < std::time::Duration::from_secs(60),
        "fragmented 4096-node rounds took {:?}",
        start.elapsed()
    );
}

/// The engine stays deterministic when its worker pool actually kicks in:
/// two identical runs at a node count large enough to shard across threads
/// produce identical results (merged in node order, per-node RNG forks).
#[test]
fn event_engine_is_deterministic_across_runs_at_scale() {
    let mut cfg = parity_cfg(24, 3, 2, false);
    cfg.problem = ProblemKind::Lasso { m: 24, h: 6, n: 24, rho: 30.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.iters = 25;
    let lcfg = LassoConfig { m: 24, h: 6, n: 24, rho: 30.0, theta: 0.1 };
    let run = || {
        let mut rngs = TrialRngs::new(cfg.seed);
        let mut p = LassoProblem::generate(lcfg, &mut rngs.data).unwrap();
        let mut eng = EventEngine::new(&cfg, &mut p, rngs).unwrap();
        for _ in 0..cfg.iters {
            eng.step_round().unwrap();
        }
        (eng.z().to_vec(), eng.accounting().total_bits())
    };
    let (z1, b1) = run();
    let (z2, b2) = run();
    assert_eq!(z1, z2);
    assert_eq!(b1, b2);
}
