//! `qadmm serve`: the socket-facing server. One acceptor thread, one
//! reader thread per connection, one writer pump per node slot, all
//! bridging into the **unchanged** [`ServerLoop`] fold path via
//! [`crate::comm::network::bridged`] mpsc endpoints — the deployment runs
//! the very state machine the in-process runtimes run, with real bytes.
//!
//! Accounting discipline: eq. (20) bits are charged **where bytes move** —
//! the reader charges the uplink when it decodes a data frame, the pump
//! charges the downlink when a write completes — and the same two points
//! tally raw socket bytes into the per-link [`super::LinkBytes`] books, so
//! [`super::reconcile`] can hold the two ledgers to exact equality. A
//! broadcast to a detached (departed) node is discarded by its pump and
//! charges nothing: only realized transmissions exist.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::comm::accounting::CommAccounting;
use crate::comm::message::{NodeToServer, ServerToNode};
use crate::comm::network::{self, SharedAccounting};
use crate::config::ExperimentConfig;
use crate::coordinator::server::ServerLoop;
use crate::coordinator::SharedProblem;
use crate::metrics::RunRecorder;
use crate::problems::Problem;
use crate::snapshot::codec::fnv1a64;
use crate::snapshot::timeline::RecordedTimeline;
use crate::topology::TopologyKind;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::frame::{Frame, PROTO_VERSION};
use super::transport::{read_frame, Endpoint, Listener, ReadOutcome, Stream};
use super::{new_books, Books, LinkBytes};

pub struct ServeOptions {
    /// A connected worker that goes silent for this long (half-open
    /// socket, hung process) is evicted — the P/τ trigger never waits on
    /// it again. Also bounds the server's own stall timeout.
    pub idle_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { idle_timeout: Duration::from_secs(30) }
    }
}

/// Everything one `serve` run produced, for reporting and verification.
pub struct ServeReport {
    pub recorder: RunRecorder,
    /// The captured production schedule (always recorded: wall-clock round
    /// times + arrival sets; the loadgen latency percentiles and the
    /// capture→replay smoke both read it).
    pub timeline: RecordedTimeline,
    /// Per-link socket byte counters — one side of the reconciliation.
    pub books: Vec<LinkBytes>,
    /// The charged eq. (20) books — the other side.
    pub accounting: CommAccounting,
    pub wall_s: f64,
}

/// The 8-byte config digest carried in the `Hello` handshake: FNV-1a over
/// the resume digest (the config JSON minus run-length fields), so a
/// worker launched with a different experiment is rejected at connect
/// time instead of corrupting the run.
pub fn config_digest(cfg: &ExperimentConfig) -> Vec<u8> {
    fnv1a64(cfg.resume_digest().as_bytes()).to_le_bytes().to_vec()
}

/// Shared state between the acceptor, readers, pumps, and `serve` itself.
struct Hub {
    n: usize,
    m: usize,
    digest: Vec<u8>,
    up_tx: Sender<NodeToServer>,
    accounting: SharedAccounting,
    books: Books,
    /// Per-node write half of the currently attached socket (None while
    /// the node is detached — its pump discards traffic).
    slots: Vec<Mutex<Option<Stream>>>,
    /// Slot claim: a second connection for an attached node is rejected.
    attached: Vec<AtomicBool>,
    /// Per-node uplink sequence stamps. Global across reconnects: the
    /// [`crate::comm::network::ServerEndpoint`] dedup compares against the
    /// last seen seq, so a rejoining node must not restart at a value its
    /// previous life just used.
    seqs: Vec<AtomicU64>,
    stop: AtomicBool,
    idle: Duration,
}

/// Run a deployment server: bind `listen`, call `on_ready` with the
/// resolved endpoint (TCP port 0 becomes the real port — this is where a
/// harness spawns its workers), then drive [`ServerLoop`] to completion
/// over the sockets and return the reconciled report.
pub fn serve<F>(
    cfg: &ExperimentConfig,
    problem: Box<dyn Problem + Send>,
    listen: &Endpoint,
    opts: &ServeOptions,
    on_ready: F,
) -> Result<ServeReport>
where
    F: FnOnce(&Endpoint) -> Result<()>,
{
    cfg.validate()?;
    ensure!(
        cfg.topology == TopologyKind::Star,
        "deploy serves the star fan-in only (aggregators are in-process engines)"
    );
    let n = problem.n_nodes();
    let m = problem.dim();

    let (listener, resolved) = Listener::bind(listen)?;
    let (ep, up_tx, down_rxs) = network::bridged(n);
    let accounting: SharedAccounting = Arc::new(Mutex::new(CommAccounting::new(n)));
    let hub = Arc::new(Hub {
        n,
        m,
        digest: config_digest(cfg),
        up_tx,
        accounting: accounting.clone(),
        books: new_books(n),
        slots: (0..n).map(|_| Mutex::new(None)).collect(),
        attached: (0..n).map(|_| AtomicBool::new(false)).collect(),
        seqs: (0..n).map(|_| AtomicU64::new(0)).collect(),
        stop: AtomicBool::new(false),
        idle: opts.idle_timeout,
    });

    let mut pumps = Vec::with_capacity(n);
    for (node, rx) in down_rxs.into_iter().enumerate() {
        let hub = hub.clone();
        pumps.push(
            std::thread::Builder::new()
                .name(format!("qadmm-pump-{node}"))
                .spawn(move || pump_loop(&hub, node, rx))?,
        );
    }
    let acceptor = {
        let hub = hub.clone();
        std::thread::Builder::new()
            .name("qadmm-accept".into())
            .spawn(move || accept_loop(&hub, listener))?
    };

    // Same state derivation as `run_threaded`: workers re-derive the
    // identical x⁰ from the shared seed, the digest guarantees they can.
    let mut root = Pcg64::seed_from_u64(cfg.seed ^ 0x7468_7265_6164);
    let mut init_rng = root.fork(100);
    let shared: SharedProblem = Arc::new(Mutex::new(problem));
    let x0 = shared.lock().unwrap().init_x(&mut init_rng);
    let clock = Stopwatch::new();
    let mut srv =
        ServerLoop::new(ep, shared, accounting.clone(), cfg, x0, m, root.fork(300));
    srv.set_record("deploy", cfg.seed);
    srv.stall_timeout = opts.idle_timeout.max(Duration::from_secs(5));

    let run_res = match on_ready(&resolved) {
        Ok(()) => srv.run(), // consumes srv; drops the endpoint → pumps drain
        Err(e) => Err(e),
    };

    // teardown in every path: stop the socket side, then read the books
    hub.stop.store(true, Ordering::SeqCst);
    for slot in &hub.slots {
        if let Some(s) = slot.lock().unwrap().as_ref() {
            s.shutdown();
        }
    }
    acceptor.join().map_err(|_| anyhow::anyhow!("acceptor thread panicked"))?;
    for p in pumps {
        p.join().map_err(|_| anyhow::anyhow!("pump thread panicked"))?;
    }

    let out = run_res?;
    let books = hub.books.lock().unwrap().clone();
    let accounting = accounting.lock().unwrap().clone();
    Ok(ServeReport {
        recorder: out.recorder,
        timeline: out.timeline.expect("deploy server always records"),
        books,
        accounting,
        wall_s: clock.elapsed_secs(),
    })
}

fn accept_loop(hub: &Arc<Hub>, listener: Listener) {
    let mut readers: Vec<JoinHandle<()>> = Vec::new();
    while !hub.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok(Some(stream)) => {
                let hub = hub.clone();
                let spawned = std::thread::Builder::new()
                    .name("qadmm-conn".into())
                    .spawn(move || connection_loop(&hub, stream));
                if let Ok(h) = spawned {
                    readers.push(h);
                }
            }
            // nothing pending (or a transient accept error): back off
            Ok(None) | Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
        readers.retain(|h| !h.is_finished());
    }
    for h in readers {
        let _ = h.join();
    }
    // listener drops here — removes the UDS socket file
}

fn connection_loop(hub: &Arc<Hub>, mut stream: Stream) {
    let node = match handshake(hub, &mut stream) {
        Ok(Some(node)) => node,
        // rejected, garbage, or vanished before Hello: never on the books
        Ok(None) | Err(_) => return,
    };
    let res = read_loop(hub, &mut stream, node);
    // detach: the pump discards traffic for this node from now on
    *hub.slots[node].lock().unwrap() = None;
    hub.attached[node].store(false, Ordering::SeqCst);
    match res {
        // clean close (acked shutdown / server stop): no eviction needed
        Ok(true) => {}
        // EOF, idle half-open, I/O error, or a protocol violation after
        // the handshake: synthesize the Leave the worker could not send
        Ok(false) | Err(_) => {
            let _ = hub.up_tx.send(NodeToServer::Leave { node });
        }
    }
}

/// Validate the `Hello` opener and claim the node's slot. `Ok(None)` means
/// the connection was rejected (a `Reject` frame was attempted) — rejected
/// connections never touch the per-link books.
fn handshake(hub: &Arc<Hub>, stream: &mut Stream) -> Result<Option<usize>> {
    let (frame, hello_bytes) = match read_frame(stream, &hub.stop, hub.idle)? {
        ReadOutcome::Frame(f, b) => (f, b),
        _ => return Ok(None),
    };
    let Frame::Hello { proto, node, m, digest } = frame else {
        anyhow::bail!("first frame was not Hello")
    };
    let reason = if proto != PROTO_VERSION {
        Some(format!("protocol version {proto} != {PROTO_VERSION}"))
    } else if digest != hub.digest {
        Some("config digest mismatch".to_string())
    } else if m as usize != hub.m {
        Some(format!("dimension {} != {m}", hub.m))
    } else if node as usize >= hub.n {
        Some(format!("node id {node} out of range (n={})", hub.n))
    } else {
        None
    };
    if let Some(reason) = reason {
        let _ = stream.write_frame(&Frame::Reject { reason });
        return Ok(None);
    }
    let node = node as usize;
    if hub.attached[node].swap(true, Ordering::SeqCst) {
        let _ = stream.write_frame(&Frame::Reject {
            reason: format!("node {node} already attached"),
        });
        return Ok(None);
    }
    // accepted: this connection is on the books from its Hello onward
    // (handshake frames are pure framing extra — charged 0 by eq. 20)
    {
        let mut b = hub.books.lock().unwrap();
        b[node].up_total += hello_bytes;
        b[node].up_extra += hello_bytes;
    }
    let wrote = stream.write_frame(&Frame::Welcome).and_then(|wb| {
        let mut b = hub.books.lock().unwrap();
        b[node].down_total += wb;
        b[node].down_extra += wb;
        stream.try_clone()
    });
    match wrote {
        Ok(write_half) => {
            *hub.slots[node].lock().unwrap() = Some(write_half);
            Ok(Some(node))
        }
        Err(e) => {
            hub.attached[node].store(false, Ordering::SeqCst);
            Err(e)
        }
    }
}

/// Decode frames off one attached connection into [`NodeToServer`]
/// messages. Returns `Ok(true)` for a clean close (shutdown ack seen, or
/// the server stopped), `Ok(false)` when the peer died (EOF/idle).
fn read_loop(hub: &Arc<Hub>, stream: &mut Stream, node: usize) -> Result<bool> {
    let mut acked = false;
    loop {
        match read_frame(stream, &hub.stop, hub.idle)? {
            ReadOutcome::Frame(f, bytes) => {
                {
                    let mut b = hub.books.lock().unwrap();
                    b[node].up_total += bytes;
                    b[node].up_extra += f.socket_extra_bytes();
                }
                let msg = match f {
                    Frame::InitFull { node: fnode, x0, u0 } => {
                        ensure!(fnode as usize == node, "InitFull for wrong node");
                        NodeToServer::InitFull { node, x0, u0 }
                    }
                    Frame::Update { node: fnode, dx_wire, du_wire } => {
                        ensure!(fnode as usize == node, "Update for wrong node");
                        let seq = hub.seqs[node].fetch_add(1, Ordering::SeqCst);
                        NodeToServer::Update { node, iter: 0, seq, dx_wire, du_wire }
                    }
                    Frame::Skip { node: fnode } => {
                        ensure!(fnode as usize == node, "Skip for wrong node");
                        let seq = hub.seqs[node].fetch_add(1, Ordering::SeqCst);
                        NodeToServer::Skip { node, seq }
                    }
                    Frame::ShutdownAck { node: fnode } => {
                        ensure!(fnode as usize == node, "ShutdownAck for wrong node");
                        acked = true;
                        NodeToServer::ShutdownAck { node }
                    }
                    other => anyhow::bail!("unexpected frame from worker: {other:?}"),
                };
                // eq. (20) charge at the byte-moving point; control frames
                // (skip/ack) stay off the books, like every other runtime
                if matches!(
                    msg,
                    NodeToServer::Update { .. } | NodeToServer::InitFull { .. }
                ) {
                    let bits = msg.wire_bits();
                    hub.accounting.lock().unwrap().record_uplink(node, bits);
                }
                if hub.up_tx.send(msg).is_err() {
                    return Ok(true); // server loop finished first
                }
            }
            ReadOutcome::Eof => return Ok(acked),
            ReadOutcome::IdleTimeout => return Ok(false),
            ReadOutcome::Stopped => return Ok(true),
        }
    }
}

/// Per-node downlink pump: owns the node's `Receiver` for the whole run
/// (across attach/detach cycles), translating [`ServerToNode`] into wire
/// frames. Detached slot → the message is discarded and **nothing** is
/// charged: eq. (20) counts realized transmissions only.
fn pump_loop(hub: &Arc<Hub>, node: usize, rx: Receiver<ServerToNode>) {
    loop {
        let msg = match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => {
                if hub.stop.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        };
        let charged = matches!(
            msg,
            ServerToNode::Consensus { .. } | ServerToNode::InitZ { .. }
        );
        let bits = msg.wire_bits();
        let frame = match msg {
            ServerToNode::Consensus { iter, included, dz_wire, last } => Frame::Consensus {
                round: iter as u32,
                // per-recipient flag instead of the id list: the pump is a
                // unicast writer, it knows who it serves
                included: included.binary_search(&(node as u32)).is_ok(),
                last,
                dz_wire,
            },
            ServerToNode::InitZ { z0 } => Frame::InitZ { z0 },
            ServerToNode::Shutdown => Frame::Shutdown,
        };
        let mut slot = hub.slots[node].lock().unwrap();
        let Some(s) = slot.as_mut() else { continue };
        match s.write_frame(&frame) {
            Ok(bytes) => {
                drop(slot);
                if charged {
                    hub.accounting.lock().unwrap().record_downlink(node, bits);
                }
                let mut b = hub.books.lock().unwrap();
                b[node].down_total += bytes;
                b[node].down_extra += frame.socket_extra_bytes();
            }
            Err(_) => {
                // write half died first: detach and evict
                *slot = None;
                drop(slot);
                let _ = hub.up_tx.send(NodeToServer::Leave { node });
            }
        }
    }
}
