//! Self-describing wire frames for compressed vectors.
//!
//! Layout (little-endian):
//! ```text
//!   u8  tag        1=dense64 2=dense32 3=qsgd 4=sign 5=topk 6=randk
//!   u32 m          vector length
//!   ... tag-specific payload ...
//! ```
//! Decoding any frame yields the exact dequantized vector the sender
//! computed — the lossy compression happens before framing; the frame
//! itself is lossless.

use super::packing::{packed_len, unpack_levels, BitReader, BitWriter};
use crate::util::rng::Pcg64;

pub const TAG_DENSE64: u8 = 1;
pub const TAG_DENSE32: u8 = 2;
pub const TAG_QSGD: u8 = 3;
pub const TAG_SIGN: u8 = 4;
pub const TAG_TOPK: u8 = 5;
pub const TAG_RANDK: u8 = 6;

/// Write the universal frame header (1-byte tag + u32 LE length) into a
/// borrowed buffer — the single definition shared by [`FrameWriter::new`]
/// and the pooled `_into` encoders (including `Qsgd::compress_into`).
pub fn frame_header_into(out: &mut Vec<u8>, tag: u8, m: usize) {
    out.push(tag);
    out.extend_from_slice(&(m as u32).to_le_bytes());
}

pub struct FrameWriter {
    buf: Vec<u8>,
}

impl FrameWriter {
    pub fn new(tag: u8, m: usize) -> Self {
        let mut buf = Vec::with_capacity(16);
        frame_header_into(&mut buf, tag, m);
        Self { buf }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

pub struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.buf.len(), "wire frame underrun");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Bounds-checked variable-length read (the public face of `take`, for
    /// codecs layered on this reader — e.g. the deploy socket protocol).
    pub fn take_bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        self.take(n)
    }

    /// Bytes not yet consumed — 0 iff the frame was read exactly.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }
}

// ---- encoders --------------------------------------------------------------

pub fn encode_dense64(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_dense64_into(v, &mut out);
    out
}

/// [`encode_dense64`] into a caller-owned buffer (cleared, capacity
/// reused) — the pooled hot path. Single source of truth for the dense64
/// frame layout.
pub fn encode_dense64_into(v: &[f64], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(5 + 8 * v.len());
    frame_header_into(out, TAG_DENSE64, v.len());
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn encode_dense32(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_dense32_into(v, &mut out);
    out
}

/// [`encode_dense32`] into a caller-owned buffer (cleared, capacity
/// reused). Single source of truth for the dense32 frame layout.
pub fn encode_dense32_into(v: &[f64], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(5 + 4 * v.len());
    frame_header_into(out, TAG_DENSE32, v.len());
    for &x in v {
        out.extend_from_slice(&(x as f32).to_le_bytes());
    }
}

pub fn encode_qsgd(levels: &[i32], norm: f64, q: u8) -> Vec<u8> {
    let mut w = FrameWriter::new(TAG_QSGD, levels.len());
    w.u8(q);
    w.f64(norm);
    w.bytes(&super::packing::pack_levels(levels, q));
    w.finish()
}

pub fn encode_sign(signs_negative: &[bool], scale: f64) -> Vec<u8> {
    let mut w = FrameWriter::new(TAG_SIGN, signs_negative.len());
    w.f64(scale);
    let mut bits = BitWriter::new();
    for &neg in signs_negative {
        bits.put(neg as u64, 1);
    }
    w.bytes(&bits.finish());
    w.finish()
}

/// Sparse top-k frame: ascending indices gap-coded with Elias-γ, values as
/// raw f64 bits in the same bitstream.
pub fn encode_topk(m: usize, entries: &[(usize, f64)]) -> Vec<u8> {
    debug_assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "indices must ascend");
    let mut w = FrameWriter::new(TAG_TOPK, m);
    w.u32(entries.len() as u32);
    let mut bits = BitWriter::new();
    let mut prev = 0usize;
    for (i, (idx, val)) in entries.iter().enumerate() {
        let gap = if i == 0 { idx + 1 } else { idx - prev };
        bits.put_elias_gamma(gap as u64);
        bits.put(val.to_bits(), 64);
        prev = *idx;
    }
    w.bytes(&bits.finish());
    w.finish()
}

pub fn encode_randk(m: usize, seed: u64, values: &[f64]) -> Vec<u8> {
    let mut w = FrameWriter::new(TAG_RANDK, m);
    w.u64(seed);
    w.u32(values.len() as u32);
    for &v in values {
        w.f64(v);
    }
    w.finish()
}

/// Re-derive the rand-k index set on the receiving side (shared seed).
pub fn randk_indices(m: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut idx = rng.choose_k(m, k);
    idx.sort_unstable();
    idx
}

// ---- streaming entry cursor ------------------------------------------------

/// Streaming `(index, value)` cursor over one frame's dequantized entries —
/// the per-tag visitor behind the fused fold path
/// ([`crate::compress::Compressed::fold_into`]).
///
/// Yields exactly the entry structure the frame *stores*, in ascending
/// index order, without materializing the dense vector: dense tags
/// (dense64/dense32/qsgd/sign) yield all m coordinates scalar-at-a-time
/// straight off the byte/bit stream; sparse tags (topk/randk) yield only
/// their k stored entries — every coordinate not yielded dequantizes to
/// exactly 0.0. Each index appears at most once. The yielded values are
/// bit-for-bit the universal [`decode`] output (which is itself built on
/// this cursor), so a zero-skip Kahan fold over the yielded entries is
/// bitwise interchangeable with materialize-then-fold (`tests/prop.rs`).
///
/// Validation matches [`decode`]: the constructor checks the header (tag,
/// length, qsgd width + payload size, k ≤ m) and iteration surfaces
/// truncation/corruption as `Err` items (bounded γ gaps, in-range
/// indices), never a panic.
pub enum Entries<'a> {
    Dense64 { r: FrameReader<'a>, i: usize, m: usize },
    Dense32 { r: FrameReader<'a>, i: usize, m: usize },
    Qsgd { bits: BitReader<'a>, q: u32, norm: f64, s: f64, i: usize, m: usize },
    Sign { bits: BitReader<'a>, scale: f64, i: usize, m: usize },
    TopK { bits: BitReader<'a>, m: usize, k: usize, i: usize, idx: usize },
    RandK { r: FrameReader<'a>, idx: Vec<usize>, i: usize },
}

/// Open a streaming entry cursor over a frame, validating the header
/// against the expected length `m` exactly as [`decode`] does.
pub fn entries(bytes: &[u8], m: usize) -> anyhow::Result<Entries<'_>> {
    let mut r = FrameReader::new(bytes);
    let tag = r.u8()?;
    let m_wire = r.u32()? as usize;
    anyhow::ensure!(m_wire == m, "frame length {m_wire} != expected {m}");
    Ok(match tag {
        TAG_DENSE64 => Entries::Dense64 { r, i: 0, m },
        TAG_DENSE32 => Entries::Dense32 { r, i: 0, m },
        TAG_QSGD => {
            let q = r.u8()?;
            anyhow::ensure!((2..=16).contains(&q), "bad qsgd width {q}");
            let norm = r.f64()?;
            let packed = r.rest();
            anyhow::ensure!(packed.len() >= packed_len(m, q), "qsgd payload too short");
            let s = ((1i32 << (q - 1)) - 1) as f64;
            Entries::Qsgd { bits: BitReader::new(packed), q: q as u32, norm, s, i: 0, m }
        }
        TAG_SIGN => {
            let scale = r.f64()?;
            Entries::Sign { bits: BitReader::new(r.rest()), scale, i: 0, m }
        }
        TAG_TOPK => {
            let k = r.u32()? as usize;
            anyhow::ensure!(k <= m, "topk k={k} > m={m}");
            Entries::TopK { bits: BitReader::new(r.rest()), m, k, i: 0, idx: 0 }
        }
        TAG_RANDK => {
            let seed = r.u64()?;
            let k = r.u32()? as usize;
            anyhow::ensure!(k <= m, "randk k={k} > m={m}");
            Entries::RandK { r, idx: randk_indices(m, k, seed), i: 0 }
        }
        t => anyhow::bail!("unknown wire tag {t}"),
    })
}

impl Iterator for Entries<'_> {
    type Item = anyhow::Result<(usize, f64)>;

    fn next(&mut self) -> Option<Self::Item> {
        match self {
            Entries::Dense64 { r, i, m } => {
                if *i >= *m {
                    return None;
                }
                let j = *i;
                *i += 1;
                Some(r.f64().map(|v| (j, v)))
            }
            Entries::Dense32 { r, i, m } => {
                if *i >= *m {
                    return None;
                }
                let j = *i;
                *i += 1;
                Some(r.f32().map(|v| (j, v as f64)))
            }
            Entries::Qsgd { bits, q, norm, s, i, m } => {
                if *i >= *m {
                    return None;
                }
                let j = *i;
                *i += 1;
                // per-field sign-magnitude decode, identical to
                // `packing::unpack_levels` one field at a time
                Some(bits.get(*q).map(|field| {
                    let sign = field & 1;
                    let mag = (field >> 1) as i32;
                    let level = if sign == 1 { -mag } else { mag };
                    (j, *norm * level as f64 / *s)
                }))
            }
            Entries::Sign { bits, scale, i, m } => {
                if *i >= *m {
                    return None;
                }
                let j = *i;
                *i += 1;
                Some(bits.get(1).map(|b| (j, if b == 1 { -*scale } else { *scale })))
            }
            Entries::TopK { bits, m, k, i, idx } => {
                if *i >= *k {
                    return None;
                }
                let first = *i == 0;
                *i += 1;
                // A corrupted γ code can decode to any u64; bound it before
                // the add so a flipped bit yields Err, never an overflow.
                let gap = match bits.get_elias_gamma() {
                    Ok(g) => g,
                    Err(e) => return Some(Err(e)),
                };
                if gap as u128 > *m as u128 {
                    return Some(Err(anyhow::anyhow!("topk gap {gap} out of range")));
                }
                let gap = gap as usize;
                let j = if first { gap - 1 } else { *idx + gap };
                if j >= *m {
                    return Some(Err(anyhow::anyhow!("topk index out of range")));
                }
                *idx = j;
                Some(bits.get(64).map(|v| (j, f64::from_bits(v))))
            }
            Entries::RandK { r, idx, i } => {
                if *i >= idx.len() {
                    return None;
                }
                let j = idx[*i];
                *i += 1;
                Some(r.f64().map(|v| (j, v)))
            }
        }
    }
}

/// The vector length a frame declares in its header, without decoding the
/// payload — what resume validation checks in-flight slots against.
pub fn frame_dim(bytes: &[u8]) -> anyhow::Result<usize> {
    let mut r = FrameReader::new(bytes);
    let tag = r.u8()?;
    anyhow::ensure!(
        (TAG_DENSE64..=TAG_RANDK).contains(&tag),
        "unknown wire tag {tag}"
    );
    Ok(r.u32()? as usize)
}

// ---- universal decoder -----------------------------------------------------

/// Decode any frame into the dense dequantized vector of length `m`.
/// Built on [`entries`] — the single source of truth for per-tag payload
/// layout — by scattering the yielded entries into a zero vector.
pub fn decode(bytes: &[u8], m: usize) -> anyhow::Result<Vec<f64>> {
    let mut out = vec![0.0; m];
    for e in entries(bytes, m)? {
        let (j, v) = e?;
        out[j] = v;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrips() {
        let v = vec![1.5, -2.25, 0.0, 1e-9];
        assert_eq!(decode(&encode_dense64(&v), 4).unwrap(), v);
        let d32 = decode(&encode_dense32(&v), 4).unwrap();
        for (a, b) in d32.iter().zip(&v) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn qsgd_frame_roundtrip() {
        let levels = vec![3, -3, 0, 1, -2, 2, 0, -1];
        let bytes = encode_qsgd(&levels, 2.5, 3);
        // header: 1 tag + 4 m + 1 q + 8 norm = 14; payload 8×3 bits = 3 bytes
        assert_eq!(bytes.len(), 14 + 3);
        let v = decode(&bytes, 8).unwrap();
        let s = 3.0;
        for (x, &l) in v.iter().zip(&levels) {
            assert_eq!(*x, 2.5 * l as f64 / s);
        }
    }

    #[test]
    fn sign_frame_roundtrip() {
        let negs = vec![true, false, false, true, true, false, true, false, true];
        let bytes = encode_sign(&negs, 0.75);
        let v = decode(&bytes, negs.len()).unwrap();
        for (x, &n) in v.iter().zip(&negs) {
            assert_eq!(*x, if n { -0.75 } else { 0.75 });
        }
    }

    #[test]
    fn topk_frame_roundtrip() {
        let entries = vec![(0usize, 1.5), (7, -0.25), (63, 1e-3)];
        let bytes = encode_topk(64, &entries);
        let v = decode(&bytes, 64).unwrap();
        let mut expect = vec![0.0; 64];
        for (i, x) in &entries {
            expect[*i] = *x;
        }
        assert_eq!(v, expect);
    }

    #[test]
    fn randk_frame_roundtrip() {
        let m = 50;
        let seed = 1234;
        let idx = randk_indices(m, 5, seed);
        let values: Vec<f64> = idx.iter().map(|&i| i as f64 * 0.5).collect();
        let bytes = encode_randk(m, seed, &values);
        let v = decode(&bytes, m).unwrap();
        for (j, &i) in idx.iter().enumerate() {
            assert_eq!(v[i], values[j]);
        }
        assert_eq!(v.iter().filter(|&&x| x != 0.0).count(), 5);
    }

    #[test]
    fn length_mismatch_rejected() {
        let bytes = encode_dense64(&[1.0, 2.0]);
        assert!(decode(&bytes, 3).is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let bytes = encode_qsgd(&[1, -1, 0, 2], 1.0, 3);
        assert!(decode(&bytes[..bytes.len() - 2], 4).is_err());
    }
}
