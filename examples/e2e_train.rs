//! End-to-end driver (full-stack proof): a **threaded** QADMM deployment —
//! server thread + N node worker threads + the PJRT ComputeService — trains
//! an MLP classifier federated over the synthetic-MNIST corpus with q = 3
//! quantized exchange and injected straggler latency, logging the loss /
//! test-accuracy curve and the exact wire traffic.
//!
//!     cargo run --release --example e2e_train -- [--iters 150] [--nodes 4]
//!         [--baseline] [--dup-prob 0.05]
//!
//! This exercises every layer at once: Pallas quantizer + JAX Adam-scan
//! graphs (inside the HLO artifacts), the PJRT runtime, the wire codec,
//! error feedback, the arrival-driven async server, and the metrics stack.
//! The run is recorded in EXPERIMENTS.md.

use qadmm::comm::network::FaultSpec;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, ProblemKind};
use qadmm::coordinator;
use qadmm::problems::nn::{NnArch, NnProblem};
use qadmm::problems::Problem;
use qadmm::runtime::artifacts::Manifest;
use qadmm::runtime::service::ComputeService;
use qadmm::util::cli::Args;
use qadmm::util::timer::{fmt_count, Stopwatch};

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let mut cfg = presets::e2e_mlp();
    cfg.iters = args.usize("iters", cfg.iters);
    cfg.seed = args.u64("seed", cfg.seed);
    let nodes = args.usize("nodes", cfg.problem.n_nodes());
    if args.flag("baseline") {
        cfg.compressor = CompressorKind::Identity;
        cfg.name = "e2e-mlp-baseline".into();
    }
    let n_train = args.usize("train", 2000);
    let n_test = args.usize("test", 512);
    let dup_prob = args.f64("dup-prob", 0.0);
    let artifact_dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let data_dir = std::path::PathBuf::from(args.str("data", "data/mnist"));
    args.finish()?;
    let (rho, lr) = match cfg.problem {
        ProblemKind::Mlp { rho, lr, .. } => (rho, lr),
        _ => unreachable!(),
    };
    cfg.problem = ProblemKind::Mlp { n: nodes, rho, lr };
    cfg.validate()?;

    println!(
        "e2e: {} | {} nodes | {} rounds | compressor {} | dup_prob {dup_prob}",
        cfg.name,
        nodes,
        cfg.iters,
        cfg.compressor.label()
    );

    let clock = Stopwatch::new();
    let service = ComputeService::start(
        artifact_dir.clone(),
        vec!["mlp_local_update".into(), "mlp_eval".into()],
    )?;
    let manifest = Manifest::load(&artifact_dir.join("manifest.json"))?;
    let problem: Box<dyn Problem + Send> = Box::new(NnProblem::new(
        NnArch::Mlp,
        nodes,
        rho,
        lr,
        Box::new(service.client()),
        &manifest,
        n_train,
        n_test,
        &data_dir,
        cfg.seed,
    )?);
    println!("problem: {}", problem.name());

    let outcome = coordinator::run_threaded(&cfg, problem, FaultSpec { dup_prob })?;

    println!("\nround  test_acc   test_loss   bits/param  batch");
    for r in &outcome.recorder.records {
        println!(
            "{:>5}  {:>8.4}  {:>10.4e}  {:>10.1}  {:>5}",
            r.iter, r.test_acc, r.loss, r.comm_bits, r.active_nodes
        );
    }
    let first = outcome.recorder.records.first().expect("no records");
    let last = outcome.recorder.records.last().expect("no records");
    println!(
        "\nwall {:.1}s | uplink {} bits | downlink {} bits | {:.1} bits/param total",
        clock.elapsed_secs(),
        fmt_count(outcome.uplink_bits as f64),
        fmt_count(outcome.downlink_bits as f64),
        outcome.normalized_bits
    );
    println!(
        "loss {:.4} -> {:.4} | test_acc {:.4} -> {:.4}",
        first.loss, last.loss, first.test_acc, last.test_acc
    );
    anyhow::ensure!(
        last.loss < first.loss && last.test_acc > first.test_acc,
        "training did not progress"
    );
    println!("OK: end-to-end threaded training improved the model");
    Ok(())
}
