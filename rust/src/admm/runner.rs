//! Monte-Carlo trial harness: run one configuration over `mc_trials`
//! independent trials (fresh data, oracle schedule and quantizer noise per
//! trial, all derived from `seed + trial`), then average the metric series
//! — exactly how the paper's figures are produced.

use crate::config::{EngineKind, ExperimentConfig};
use crate::metrics::RunRecorder;
use crate::problems::Problem;
use crate::util::stats;

use super::engine::EventEngine;
use super::sim::{AsyncSim, TrialRngs};

/// Averaged curves across trials (aligned on the eval grid).
#[derive(Clone, Debug)]
pub struct McResult {
    pub trials: Vec<RunRecorder>,
    pub iters: Vec<f64>,
    pub mean_accuracy: Vec<f64>,
    pub mean_test_acc: Vec<f64>,
    pub mean_loss: Vec<f64>,
    pub mean_comm_bits: Vec<f64>,
}

impl McResult {
    fn from_trials(trials: Vec<RunRecorder>) -> Self {
        assert!(!trials.is_empty());
        let len = trials.iter().map(|t| t.records.len()).min().unwrap();
        let trimmed: Vec<Vec<&crate::metrics::IterRecord>> =
            trials.iter().map(|t| t.records.iter().take(len).collect()).collect();
        let series = |f: &dyn Fn(&crate::metrics::IterRecord) -> f64| -> Vec<Vec<f64>> {
            trimmed.iter().map(|t| t.iter().map(|r| f(r)).collect()).collect()
        };
        let iters = trimmed[0].iter().map(|r| r.iter as f64).collect();
        let mean_accuracy = stats::mean_series(&series(&|r| r.accuracy));
        let mean_test_acc = stats::mean_series(&series(&|r| r.test_acc));
        let mean_loss = stats::mean_series(&series(&|r| r.loss));
        let mean_comm_bits = stats::mean_series(&series(&|r| r.comm_bits));
        Self { trials, iters, mean_accuracy, mean_test_acc, mean_loss, mean_comm_bits }
    }

    /// A recorder carrying the averaged series (for the summary helpers).
    pub fn mean_recorder(&self) -> RunRecorder {
        let mut rec = RunRecorder::new();
        for i in 0..self.iters.len() {
            rec.push(crate::metrics::IterRecord {
                iter: self.iters[i] as usize,
                comm_bits: self.mean_comm_bits[i],
                accuracy: self.mean_accuracy[i],
                test_acc: self.mean_test_acc[i],
                loss: self.mean_loss[i],
                active_nodes: 0,
                wall_s: 0.0,
            });
        }
        rec
    }
}

/// Builds a fresh problem for each trial. Receives the trial seed and the
/// dedicated data RNG (fork 1 of the trial root) so that, for a fixed seed,
/// every configuration sees identical data.
pub type ProblemFactory<'f> =
    dyn FnMut(u64, &mut crate::util::rng::Pcg64) -> anyhow::Result<Box<dyn Problem>> + 'f;

pub fn trial_seed(base_seed: u64, trial: usize) -> u64 {
    base_seed.wrapping_add(1_000_003u64.wrapping_mul(trial as u64 + 1))
}

/// Run `cfg.mc_trials` trials and average. `cfg.engine` picks the in-process
/// engine (seq | event); the threaded deployment has its own entry point
/// ([`crate::coordinator::run_threaded`]) because it needs `Problem + Send`.
pub fn run_mc(cfg: &ExperimentConfig, factory: &mut ProblemFactory) -> anyhow::Result<McResult> {
    cfg.validate()?;
    let mut trials = Vec::with_capacity(cfg.mc_trials);
    for t in 0..cfg.mc_trials {
        let seed = trial_seed(cfg.seed, t);
        let mut rngs = TrialRngs::new(seed);
        let mut problem = factory(seed, &mut rngs.data)?;
        let recorder = match cfg.engine {
            EngineKind::Seq => AsyncSim::new(cfg, problem.as_mut(), rngs)?.run(cfg.iters)?,
            EngineKind::Event => {
                EventEngine::new(cfg, problem.as_mut(), rngs)?.run(cfg.iters)?
            }
            EngineKind::Threaded => anyhow::bail!(
                "run_mc drives in-process engines; use coordinator::run_threaded for engine=threaded"
            ),
        };
        crate::util::log::debug(
            "runner",
            &format!("{}: trial {t} done ({} records)", cfg.name, recorder.records.len()),
        );
        trials.push(recorder);
    }
    Ok(McResult::from_trials(trials))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::problems::lasso::{LassoConfig, LassoProblem};

    fn lasso_factory(
        cfg: &ExperimentConfig,
    ) -> impl FnMut(u64, &mut crate::util::rng::Pcg64) -> anyhow::Result<Box<dyn Problem>> + '_
    {
        move |_seed, data_rng| {
            let (m, h, n, rho, theta) = match cfg.problem {
                crate::config::ProblemKind::Lasso { m, h, n, rho, theta } => {
                    (m, h, n, rho, theta)
                }
                _ => unreachable!(),
            };
            let p =
                LassoProblem::generate(LassoConfig { m, h, n, rho, theta }, data_rng)?;
            Ok(Box::new(p) as Box<dyn Problem>)
        }
    }

    #[test]
    fn qadmm_converges_on_small_lasso() {
        let mut cfg = presets::ci_lasso();
        cfg.mc_trials = 2;
        cfg.iters = 250;
        let mut factory = lasso_factory(&cfg);
        let res = run_mc(&cfg, &mut factory).unwrap();
        assert_eq!(res.trials.len(), 2);
        let last = *res.mean_accuracy.last().unwrap();
        let first = res.mean_accuracy[0];
        assert!(last < 1e-6, "final accuracy {last}");
        assert!(last < first * 1e-3, "no convergence: {first} -> {last}");
        // comm bits strictly increasing
        assert!(res.mean_comm_bits.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn event_engine_matches_seq_in_parity_config() {
        // identity compressor + zero latency: the virtual timeline collapses
        // onto the simulator's rounds and the curves are bit-identical
        let mut cfg = presets::ci_lasso();
        cfg.compressor = crate::compress::CompressorKind::Identity;
        cfg.iters = 60;
        cfg.mc_trials = 1;
        let mut f1 = lasso_factory(&cfg);
        let seq = run_mc(&cfg, &mut f1).unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.engine = crate::config::EngineKind::Event;
        let mut f2 = lasso_factory(&cfg2);
        let ev = run_mc(&cfg2, &mut f2).unwrap();
        assert_eq!(seq.mean_accuracy, ev.mean_accuracy);
        assert_eq!(seq.mean_comm_bits, ev.mean_comm_bits);
    }

    #[test]
    fn identical_seed_identical_trajectories() {
        let cfg = presets::ci_lasso();
        let mut f1 = lasso_factory(&cfg);
        let a = run_mc(&cfg, &mut f1).unwrap();
        let mut f2 = lasso_factory(&cfg);
        let b = run_mc(&cfg, &mut f2).unwrap();
        assert_eq!(a.mean_accuracy, b.mean_accuracy);
        assert_eq!(a.mean_comm_bits, b.mean_comm_bits);
    }

    #[test]
    fn baseline_uses_more_bits_for_same_iterations() {
        let cfg = presets::ci_lasso();
        let mut f = lasso_factory(&cfg);
        let q = run_mc(&cfg, &mut f).unwrap();
        let mut base_cfg = cfg.clone();
        base_cfg.compressor = crate::compress::CompressorKind::Identity;
        let mut f2 = lasso_factory(&base_cfg);
        let b = run_mc(&base_cfg, &mut f2).unwrap();
        let q_bits = *q.mean_comm_bits.last().unwrap();
        let b_bits = *b.mean_comm_bits.last().unwrap();
        assert!(
            q_bits < 0.2 * b_bits,
            "expected ≥80% wire reduction: qadmm={q_bits} baseline={b_bits}"
        );
        // and both converge comparably
        let qa = *q.mean_accuracy.last().unwrap();
        let ba = *b.mean_accuracy.last().unwrap();
        assert!(qa < 1e-6 && ba < 1e-6, "qadmm={qa} baseline={ba}");
    }
}
