//! Event-engine scaling sweep: n ∈ {16, 128, 1024} nodes, plus a
//! τ × downlink-delay grid at n ∈ {256, 1024}.
//!
//! The headline configuration is the acceptance bar for the virtual-time
//! engine: **n = 1024 nodes, m = 10240-dim LASSO, 200 consensus rounds,
//! heterogeneous straggler latency — in seconds of wall-clock, not hours**
//! (the threaded runtime would sleep through every injected delay; the
//! sequential simulator has no notion of stragglers at all). Feasible
//! because the LASSO Woodbury solver never forms an m×m inverse (h ≪ m)
//! and the per-node fan-out runs on the worker pool.
//!
//! The downlink grid exercises the per-link decomposition end to end:
//! delayed ẑ delivery multiplies `DownlinkArrive` events and fragments the
//! dispatch batches, which is exactly the regime the mirror bookkeeping
//! has to keep cheap.
//!
//! `QADMM_BENCH_FAST=1` shrinks both sweeps for CI smoke runs.

use qadmm::admm::engine::EventEngine;
use qadmm::admm::sim::TrialRngs;
use qadmm::comm::latency::LatencyModel;
use qadmm::comm::profile::LinkConfig;
use qadmm::config::{presets, EngineKind, ExperimentConfig, OracleConfig, ProblemKind};
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::util::timer::{fmt_count, Stopwatch};

struct Sweep {
    n: usize,
    m: usize,
    h: usize,
    rounds: usize,
    tau: usize,
    link: LinkConfig,
    label: &'static str,
}

/// The straggler mixture of the original scaling sweep, split across the
/// compute and uplink legs (virtual seconds).
fn straggler_link() -> LinkConfig {
    let mix = LatencyModel::Mixture { fast: 0.002, slow: 0.25, p_slow: 0.15 };
    LinkConfig { compute: mix, uplink: mix, downlink: LatencyModel::None, clock_drift: 0.0 }
}

fn base_cfg(s: &Sweep) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    cfg.name = format!("engine-scale-n{}-{}", s.n, s.label);
    cfg.problem = ProblemKind::Lasso { m: s.m, h: s.h, n: s.n, rho: 50.0, theta: 0.1 };
    cfg.engine = EngineKind::Event;
    cfg.tau = s.tau;
    cfg.p_min = (s.n / 4).max(1);
    cfg.iters = s.rounds;
    cfg.mc_trials = 1;
    cfg.eval_every = s.rounds; // one final eval; per-round eval is O(n·h·m)
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
    // Injected delays in *virtual* seconds: a threaded run would sleep
    // ~rounds × slow-tail of real time; the engine only does arithmetic.
    cfg.link = s.link;
    cfg
}

fn run_sweep(s: &Sweep) -> anyhow::Result<()> {
    let cfg = base_cfg(s);
    let gen_clock = Stopwatch::new();
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut problem = LassoProblem::generate(
        LassoConfig { m: s.m, h: s.h, n: s.n, rho: 50.0, theta: 0.1 },
        &mut rngs.data,
    )?;
    // The accuracy metric needs F*, which costs thousands of reference
    // rounds — irrelevant for a throughput bench.
    problem.set_reference_optimum(1.0);
    let gen_s = gen_clock.elapsed_secs();

    let clock = Stopwatch::new();
    let mut engine = EventEngine::new(&cfg, &mut problem, rngs)?;
    for _ in 0..s.rounds {
        engine.step_round()?;
    }
    let wall = clock.elapsed_secs();
    let stats = engine.stats();
    println!(
        "{:24} n={:5} m={:6} tau={:2} rounds={:4}  wall {:7.2}s (gen {:5.2}s)  \
         virtual {:8.2}s  speedup {:>9}x  events/s {:>9}  dispatches {}",
        s.label,
        s.n,
        s.m,
        s.tau,
        s.rounds,
        wall,
        gen_s,
        stats.virtual_time,
        fmt_count(stats.virtual_time / wall.max(1e-9)),
        fmt_count(stats.events as f64 / wall.max(1e-9)),
        stats.dispatches,
    );
    if s.n >= 1024 && wall >= 10.0 {
        println!("  !! acceptance bar missed: n={} took {wall:.2}s (target < 10s)", s.n);
    }
    Ok(())
}

fn scale_sweep(n: usize, m: usize, h: usize, rounds: usize) -> Sweep {
    Sweep { n, m, h, rounds, tau: 4, link: straggler_link(), label: "scale" }
}

fn main() {
    let fast = std::env::var("QADMM_BENCH_FAST").is_ok();
    let mut sweeps = if fast {
        vec![
            scale_sweep(16, 200, 100, 50),
            scale_sweep(128, 512, 16, 20),
            scale_sweep(1024, 10_240, 4, 10),
        ]
    } else {
        vec![
            scale_sweep(16, 200, 100, 200),
            scale_sweep(128, 2048, 16, 200),
            scale_sweep(1024, 10_240, 4, 200),
        ]
    };

    // τ × downlink grid at n ∈ {256, 1024} (fast mode keeps n = 256 only):
    // delayed ẑ delivery is the per-link decomposition's hot path.
    let downlinks: [(LatencyModel, &'static str); 2] = [
        (LatencyModel::Const(0.05), "tauxdown-const"),
        (LatencyModel::Exp(0.25), "tauxdown-exp"),
    ];
    let grid_sizes: &[usize] = if fast { &[256] } else { &[256, 1024] };
    let grid_rounds = if fast { 10 } else { 100 };
    for &n in grid_sizes {
        for tau in [2usize, 8] {
            for (down, label) in downlinks {
                sweeps.push(Sweep {
                    n,
                    m: 1024,
                    h: 8,
                    rounds: grid_rounds,
                    tau,
                    link: LinkConfig {
                        compute: LatencyModel::Exp(0.01),
                        uplink: LatencyModel::Exp(0.01),
                        downlink: down,
                        clock_drift: 0.05,
                    },
                    label,
                });
            }
        }
    }

    println!("--- engine_scale: event-driven virtual-time QADMM ---");
    for s in &sweeps {
        if let Err(e) = run_sweep(s) {
            eprintln!("n={} ({}): {e:#}", s.n, s.label);
            std::process::exit(1);
        }
    }
    println!("--- engine_scale: {} sweeps done ---", sweeps.len());
}
