//! `qadmm worker`: the node side of the deployment. A single-threaded
//! socket client running the same local state machine as
//! [`crate::coordinator::node::NodeWorker`] — handshake, full-precision
//! init upload, then the Fig. 2 cadence (compute on inclusion, one update
//! in flight) with the event-trigger dead-band and adaptive quantizer
//! intact. The worker re-derives x⁰ and its RNG stream from the shared
//! config seed, exactly as `run_threaded` does — the handshake digest is
//! what makes that sound.

use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::admm::trigger::{inf_norm, TriggerState};
use crate::compress::error_feedback::EstimateTracker;
use crate::compress::{wire, Compressor};
use crate::config::ExperimentConfig;
use crate::problems::Problem;
use crate::util::rng::Pcg64;

use super::frame::{Frame, PROTO_VERSION};
use super::server::config_digest;
use super::transport::{read_frame_blocking, Endpoint, ReadOutcome, Stream};

pub struct WorkerOptions {
    pub node: usize,
    /// How long the server may legitimately stay quiet (other nodes
    /// holding up a round) before this worker gives up.
    pub idle_timeout: Duration,
    /// Churn injection for tests: sever the connection abruptly — no ack,
    /// no goodbye — after sending this many updates.
    pub die_after_updates: Option<u64>,
    /// Connect attempts before giving up. A loadgen burst of hundreds of
    /// simultaneous connects can overflow the listen backlog; a refused
    /// connect must not kill the worker permanently.
    pub connect_attempts: u32,
    /// First retry delay; doubles per attempt (capped inside
    /// [`Stream::connect_retry`]).
    pub connect_backoff: Duration,
}

impl WorkerOptions {
    pub fn new(node: usize) -> Self {
        Self {
            node,
            idle_timeout: Duration::from_secs(60),
            die_after_updates: None,
            connect_attempts: 8,
            connect_backoff: Duration::from_millis(10),
        }
    }
}

#[derive(Debug, Default)]
pub struct WorkerReport {
    pub updates_sent: u64,
    pub skips_sent: u64,
    /// Consensus broadcasts applied (post-init rounds this worker saw).
    pub rounds_applied: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Exited via the last-flagged broadcast + ack (orderly drain) rather
    /// than an injected death.
    pub acked_shutdown: bool,
}

/// Connect, handshake, and run the node loop to completion.
pub fn run_worker(
    cfg: &ExperimentConfig,
    mut problem: Box<dyn Problem + Send>,
    connect: &Endpoint,
    opts: &WorkerOptions,
) -> Result<WorkerReport> {
    cfg.validate()?;
    let n = problem.n_nodes();
    let m = problem.dim();
    ensure!(opts.node < n, "node id {} out of range (n={n})", opts.node);
    ensure!(opts.node <= u16::MAX as usize, "deploy node ids are u16 on the wire");

    // identical derivation to run_threaded / serve: same x⁰, same per-node
    // RNG stream, so a deployment is the threaded run with real sockets
    let mut root = Pcg64::seed_from_u64(cfg.seed ^ 0x7468_7265_6164);
    let mut init_rng = root.fork(100);
    let x0 = problem.init_x(&mut init_rng);
    let mut rng = root.fork(200 + opts.node as u64);

    let mut stream =
        Stream::connect_retry(connect, opts.connect_attempts, opts.connect_backoff)?;
    stream.tune();
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut report = WorkerReport::default();

    report.bytes_up += stream.write_frame(&Frame::Hello {
        proto: PROTO_VERSION,
        node: opts.node as u32,
        m: m as u32,
        digest: config_digest(cfg),
    })?;
    match read_frame_blocking(&mut stream, opts.idle_timeout)? {
        ReadOutcome::Frame(Frame::Welcome, b) => report.bytes_down += b,
        ReadOutcome::Frame(Frame::Reject { reason }, _) => {
            bail!("server rejected handshake: {reason}")
        }
        ReadOutcome::Frame(f, _) => bail!("expected Welcome, got {f:?}"),
        _ => bail!("server closed the connection during the handshake"),
    }

    let ef = cfg.error_feedback;
    let mut x = x0.clone();
    let mut u = vec![0.0; m];
    let mut xhat = EstimateTracker::new(x0.clone(), ef);
    let mut uhat = EstimateTracker::new(vec![0.0; m], ef);
    let mut zhat: Option<EstimateTracker> = None;
    let mut trigger = TriggerState::new(cfg, 1);
    let compressor = cfg.compressor.build();

    report.bytes_up += stream.write_frame(&Frame::InitFull {
        node: opts.node as u32,
        x0: x.clone(),
        u0: u.clone(),
    })?;

    loop {
        match read_frame_blocking(&mut stream, opts.idle_timeout)? {
            ReadOutcome::Frame(Frame::InitZ { z0 }, b) => {
                report.bytes_down += b;
                ensure!(z0.len() == m, "InitZ dimension mismatch");
                // fresh downlink basis (first join *and* rejoin): all
                // subsequent C(Δz) deltas apply on this estimate
                zhat = Some(EstimateTracker::new(z0, ef));
                if !compute_and_send(
                    &mut stream,
                    problem.as_mut(),
                    opts,
                    &mut rng,
                    &mut x,
                    &mut u,
                    &mut xhat,
                    &mut uhat,
                    zhat.as_ref().unwrap(),
                    &mut trigger,
                    compressor.as_ref(),
                    &mut report,
                )? {
                    return Ok(report); // injected death: drop the socket
                }
            }
            ReadOutcome::Frame(Frame::Consensus { included, last, dz_wire, .. }, b) => {
                report.bytes_down += b;
                if let Some(zh) = zhat.as_mut() {
                    let dz = wire::decode(&dz_wire, m)?;
                    zh.commit(&dz);
                    report.rounds_applied += 1;
                } // else: pre-rebase broadcast raced our rejoin InitZ — drop
                if last {
                    report.bytes_up += stream
                        .write_frame(&Frame::ShutdownAck { node: opts.node as u16 })?;
                    report.acked_shutdown = true;
                    return Ok(report);
                }
                let alive = match zhat.as_ref() {
                    Some(zh) if included => compute_and_send(
                        &mut stream,
                        problem.as_mut(),
                        opts,
                        &mut rng,
                        &mut x,
                        &mut u,
                        &mut xhat,
                        &mut uhat,
                        zh,
                        &mut trigger,
                        compressor.as_ref(),
                        &mut report,
                    )?,
                    _ => true,
                };
                if !alive {
                    return Ok(report);
                }
            }
            ReadOutcome::Frame(Frame::Shutdown, _) => return Ok(report),
            ReadOutcome::Frame(f, _) => bail!("unexpected frame from server: {f:?}"),
            ReadOutcome::Eof => bail!("server closed the connection mid-run"),
            ReadOutcome::IdleTimeout => {
                bail!("server idle past {:?}", opts.idle_timeout)
            }
            ReadOutcome::Stopped => unreachable!("worker reads have no stop flag"),
        }
    }
}

/// One local update + dispatch, mirroring `NodeWorker::compute_and_send`
/// (same trigger/EF/commit order, so the quantized trajectory matches the
/// in-process runtimes given the same arrival schedule). Returns false
/// when an injected death severed the connection.
#[allow(clippy::too_many_arguments)]
fn compute_and_send(
    stream: &mut Stream,
    problem: &mut (dyn Problem + Send),
    opts: &WorkerOptions,
    rng: &mut Pcg64,
    x: &mut Vec<f64>,
    u: &mut Vec<f64>,
    xhat: &mut EstimateTracker,
    uhat: &mut EstimateTracker,
    zhat: &EstimateTracker,
    trigger: &mut TriggerState,
    compressor: &dyn Compressor,
    report: &mut WorkerReport,
) -> Result<bool> {
    let m = x.len();
    let z = zhat.estimate().to_vec();
    let (x_new, _loss) = problem.local_update(opts.node, &z, u, x, rng)?;
    for j in 0..m {
        u[j] += x_new[j] - z[j];
    }
    *x = x_new;
    let mut dx = Vec::with_capacity(m);
    let mut du = Vec::with_capacity(m);
    xhat.peek_delta_into(x, &mut dx);
    uhat.peek_delta_into(u, &mut du);
    if trigger.enabled() {
        let norm = inf_norm(&dx).max(inf_norm(&du));
        trigger.observe(0, norm);
        if !trigger.should_send(norm) {
            trigger.note_skip();
            report.bytes_up +=
                stream.write_frame(&Frame::Skip { node: opts.node as u16 })?;
            report.skips_sent += 1;
            return Ok(true);
        }
    }
    xhat.note_sent(x);
    uhat.note_sent(u);
    let (cx, cu) = match trigger.compressor_for(0) {
        Some(q) => (q.compress(&dx, rng), q.compress(&du, rng)),
        None => (compressor.compress(&dx, rng), compressor.compress(&du, rng)),
    };
    xhat.commit_frame(&cx)?;
    uhat.commit_frame(&cu)?;
    report.bytes_up += stream.write_frame(&Frame::Update {
        node: opts.node as u16,
        dx_wire: cx.wire,
        du_wire: cu.wire,
    })?;
    report.updates_sent += 1;
    if let Some(limit) = opts.die_after_updates {
        if report.updates_sent >= limit {
            // abrupt churn: no ack, no goodbye — the server's reader sees
            // EOF and synthesizes the Leave
            stream.shutdown();
            return Ok(false);
        }
    }
    Ok(true)
}
