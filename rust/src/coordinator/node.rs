//! Node worker thread: receives consensus broadcasts, catches up on any
//! backlog (a straggler applies every missed C(Δz) in order — the estimate
//! stream is cumulative), runs its local update, and ships compressed
//! deltas back to the server.

use crate::admm::trigger::{inf_norm, TriggerState};
use crate::comm::message::{NodeToServer, ServerToNode};
use crate::comm::network::NodeEndpoint;
use crate::compress::error_feedback::EstimateTracker;
use crate::compress::{wire, Compressor};
use crate::config::ExperimentConfig;
use crate::util::rng::Pcg64;

use super::SharedProblem;

pub struct NodeWorker {
    ep: NodeEndpoint,
    problem: SharedProblem,
    compressor: Box<dyn Compressor>,
    ef: bool,
    m: usize,
    x: Vec<f64>,
    u: Vec<f64>,
    xhat: EstimateTracker,
    uhat: EstimateTracker,
    zhat: Option<EstimateTracker>,
    /// This node's event-trigger / adaptive-schedule state (a fleet of
    /// one: the worker owns only its own node, index 0).
    trigger: TriggerState,
    rng: Pcg64,
}

impl NodeWorker {
    pub fn new(
        ep: NodeEndpoint,
        problem: SharedProblem,
        cfg: &ExperimentConfig,
        x0: Vec<f64>,
        rng: Pcg64,
    ) -> Self {
        let m = x0.len();
        Self {
            ep,
            problem,
            compressor: cfg.compressor.build(),
            ef: cfg.error_feedback,
            m,
            x: x0.clone(),
            u: vec![0.0; m],
            xhat: EstimateTracker::new(x0, cfg.error_feedback),
            uhat: EstimateTracker::new(vec![0.0; m], cfg.error_feedback),
            zhat: None,
            trigger: TriggerState::new(cfg, 1),
            rng,
        }
    }

    pub fn node_id(&self) -> usize {
        self.ep.node
    }

    pub fn run(mut self) -> anyhow::Result<()> {
        // Algorithm 1 lines 1–4: full-precision initial upload.
        self.ep.send(NodeToServer::InitFull {
            node: self.ep.node,
            x0: self.x.clone(),
            u0: self.u.clone(),
        })?;
        loop {
            let msg = self.ep.recv()?;
            match msg {
                ServerToNode::InitZ { z0 } => {
                    self.zhat = Some(EstimateTracker::new(z0, self.ef));
                    if !self.compute_and_send()? {
                        break;
                    }
                }
                ServerToNode::Consensus { included, dz_wire, last, .. } => {
                    self.apply_consensus(&dz_wire)?;
                    let mut included = included.binary_search(&(self.ep.node as u32)).is_ok();
                    let mut last = last;
                    // Catch up: a straggler may have a backlog of broadcasts;
                    // apply every missed delta before computing once. A
                    // `last` anywhere in the backlog ends the run — every
                    // delta up to and including it is still applied, so the
                    // final ẑ mirror is complete before the ack.
                    let mut shutdown = false;
                    while let Some(extra) = self.ep.try_recv() {
                        match extra {
                            ServerToNode::Consensus { included: inc, dz_wire, last: l, .. } => {
                                self.apply_consensus(&dz_wire)?;
                                included |= inc.binary_search(&(self.ep.node as u32)).is_ok();
                                last |= l;
                            }
                            ServerToNode::Shutdown => {
                                shutdown = true;
                                break;
                            }
                            ServerToNode::InitZ { .. } => {}
                        }
                    }
                    if last {
                        // Drain-then-close handshake: tell the server the
                        // final delta landed, then exit. After the ack no
                        // frame of ours is in flight, so the books are
                        // final the moment the server has all acks.
                        let _ = self.ep.send(NodeToServer::ShutdownAck { node: self.ep.node });
                        break;
                    }
                    if shutdown {
                        break;
                    }
                    // One update in flight per node (Fig. 2 cadence): only
                    // compute again once the server has incorporated the
                    // previous update into a consensus we have seen.
                    if included && !self.compute_and_send()? {
                        break;
                    }
                }
                ServerToNode::Shutdown => break,
            }
        }
        Ok(())
    }

    fn apply_consensus(&mut self, dz_wire: &[u8]) -> anyhow::Result<()> {
        let dz = wire::decode(dz_wire, self.m)?;
        self.zhat
            .as_mut()
            .expect("consensus before InitZ")
            .commit(&dz);
        Ok(())
    }

    /// Returns false when the server has hung up (treated as shutdown).
    fn compute_and_send(&mut self) -> anyhow::Result<bool> {
        let zhat = self.zhat.as_ref().expect("no consensus yet").estimate().to_vec();
        let (x_new, _loss) = {
            let mut p = self.problem.lock().unwrap();
            p.local_update(self.ep.node, &zhat, &self.u, &self.x, &mut self.rng)?
        };
        // Injected compute time (scaled by this node's clock drift),
        // outside the problem lock so other nodes keep computing.
        self.ep.inject_compute_delay();
        for j in 0..self.m {
            self.u[j] += x_new[j] - zhat[j];
        }
        self.x = x_new;
        // Event trigger: peek the EF-adjusted deltas first; within the
        // dead-band the payload is withheld and a zero-bit Skip carries
        // the arrival credit instead (no bank mutation, no quantizer
        // draw). peek + note_sent == the old make_delta, so the disabled
        // path is byte-for-byte the pre-trigger behavior.
        let mut dx = Vec::with_capacity(self.m);
        let mut du = Vec::with_capacity(self.m);
        self.xhat.peek_delta_into(&self.x, &mut dx);
        self.uhat.peek_delta_into(&self.u, &mut du);
        if self.trigger.enabled() {
            let norm = inf_norm(&dx).max(inf_norm(&du));
            self.trigger.observe(0, norm);
            if !self.trigger.should_send(norm) {
                self.trigger.note_skip();
                let sent = self.ep.send(NodeToServer::Skip {
                    node: self.ep.node,
                    seq: 0, // stamped by the endpoint
                });
                return Ok(sent.is_ok());
            }
        }
        self.xhat.note_sent(&self.x);
        self.uhat.note_sent(&self.u);
        let (cx, cu) = match self.trigger.compressor_for(0) {
            // adaptive schedule: this node's current QSGD width
            Some(q) => {
                (q.compress(&dx, &mut self.rng), q.compress(&du, &mut self.rng))
            }
            None => (
                self.compressor.compress(&dx, &mut self.rng),
                self.compressor.compress(&du, &mut self.rng),
            ),
        };
        // Frame commit before the wire buffers move into the message: the
        // sender advances its banks by exactly what the server will decode.
        self.xhat.commit_frame(&cx)?;
        self.uhat.commit_frame(&cu)?;
        let sent = self.ep.send(NodeToServer::Update {
            node: self.ep.node,
            iter: 0,
            seq: 0, // stamped by the endpoint
            dx_wire: cx.wire,
            du_wire: cu.wire,
        });
        // A send failure after the server finished its rounds is an orderly
        // shutdown race, not an error.
        Ok(sent.is_ok())
    }
}
