//! Reproduce **Figure 3** (§5.1): LASSO accuracy (eq. 19) vs iterations and
//! vs communication bits, QADMM (q = 3) against unquantized async ADMM, for
//! τ ∈ {1, 3}, with the paper's parameters
//! (M, ρ, θ, N, H) = (200, 500, 0.1, 16, 100), P = 1, two-group oracle.
//!
//!     cargo run --release --example lasso_fig3 -- [--iters 700] [--trials 10]
//!         [--backend hlo|native] [--quick]
//!
//! Writes `out/fig3_tau{1,3}_{qadmm,baseline}.csv` (mean curves over the MC
//! trials) and prints the headline reduction at accuracy 1e-10.

use qadmm::config::{presets, Backend};
use qadmm::exp::fig3::{self, Fig3Options};
use qadmm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let quick = args.flag("quick");
    let mut opts = Fig3Options {
        iters: args.usize("iters", if quick { 250 } else { presets::fig3(3).iters }),
        mc_trials: args.usize("trials", if quick { 2 } else { presets::fig3(3).mc_trials }),
        target: args.f64("target", if quick { 1e-8 } else { 1e-10 }),
        out_dir: args.str("out", "out").into(),
        artifact_dir: args.str("artifacts", "artifacts").into(),
        ..Default::default()
    };
    match args.str("backend", "hlo").as_str() {
        "native" => opts.backend = Backend::Native,
        "hlo" => opts.backend = Backend::Hlo,
        other => anyhow::bail!("unknown backend '{other}'"),
    }
    args.finish()?;

    println!(
        "fig3: taus={:?} iters={} trials={} backend={:?}",
        opts.taus, opts.iters, opts.mc_trials, opts.backend
    );
    let summary = fig3::run(&opts)?;
    for s in &summary.series {
        println!("--- {} (accuracy milestones) ---", s.label);
        print!("{}", qadmm::exp::milestones(&s.mean_recorder(), |r| r.accuracy));
    }
    println!();
    for h in &summary.headline {
        println!("{h}");
    }
    println!("CSV series in {}", opts.out_dir.display());
    Ok(())
}
