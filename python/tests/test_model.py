"""Semantics of the L2 graphs: KKT optimality of the exact updates,
consensus prox correctness, Lagrangian values, and an end-to-end pure-jnp
sync-ADMM convergence run using exactly the functions that get lowered.
"""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.kernels.ref import soft_threshold_ref  # noqa: E402


def lasso_data(m=24, h=16, n=4, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, h, m))
    z0 = np.zeros(m)
    nz = rng.choice(m, size=max(1, m // 5), replace=False)
    z0[nz] = rng.standard_normal(len(nz))
    b = np.einsum("nhm,m->nh", a, z0) + 0.1 * rng.standard_normal((n, h))
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(z0)


def precompute(a, b, rho):
    n, h, m = a.shape
    ata = jnp.einsum("nhm,nhk->nmk", a, a)
    atb2 = 2.0 * jnp.einsum("nhm,nh->nm", a, b)
    btb = jnp.sum(b * b, axis=1)
    minv = jnp.linalg.inv(2.0 * ata + rho * jnp.eye(m)[None])
    return ata, atb2, btb, minv


def test_node_step_kkt():
    """The exact primal update satisfies 2AᵀAx − 2Aᵀb + ρ(x − ẑ + u) = 0."""
    rho, s = 5.0, 3.0
    a, b, _ = lasso_data()
    ata, atb2, btb, minv = precompute(a, b, rho)
    m = a.shape[2]
    rng = np.random.default_rng(1)
    zhat = jnp.asarray(rng.standard_normal(m))
    u = jnp.asarray(rng.standard_normal(m) * 0.1)
    xhat = jnp.asarray(rng.standard_normal(m))
    uhat = jnp.asarray(rng.standard_normal(m))
    noise = jnp.asarray(rng.random(m))
    out = model.lasso_node_step(
        minv[0], atb2[0], zhat, u, xhat, uhat, noise, noise, rho, s
    )
    x_new, u_new = out[0], out[1]
    grad = 2.0 * ata[0] @ x_new - atb2[0] + rho * (x_new - zhat + u)
    np.testing.assert_allclose(np.asarray(grad), 0, atol=1e-9)
    np.testing.assert_allclose(
        np.asarray(u_new), np.asarray(u + x_new - zhat), atol=1e-12
    )


def test_node_step_delta_is_error_feedback_form():
    """Δx must equal x_new − x̂ (current change + previous error, eq. 10);
    verified through the dequantized output: C(Δx) reconstructs from levels
    with ‖x_new − x̂‖_max."""
    rho, s = 5.0, 7.0
    a, b, _ = lasso_data(seed=3)
    _, atb2, _, minv = precompute(a, b, rho)
    m = a.shape[2]
    rng = np.random.default_rng(4)
    zhat = jnp.asarray(rng.standard_normal(m))
    u = jnp.asarray(rng.standard_normal(m) * 0.1)
    xhat = jnp.asarray(rng.standard_normal(m))
    uhat = jnp.asarray(rng.standard_normal(m))
    nx = jnp.asarray(rng.random(m))
    nu = jnp.asarray(rng.random(m))
    (x_new, _, cx_val, cx_lvl, cx_norm, _, _, _) = model.lasso_node_step(
        minv[0], atb2[0], zhat, u, xhat, uhat, nx, nu, rho, s
    )
    dx = np.asarray(x_new - xhat)
    assert abs(float(cx_norm) - np.abs(dx).max()) < 1e-12
    np.testing.assert_allclose(
        np.asarray(cx_val), np.asarray(cx_lvl) * float(cx_norm) / s, atol=1e-12
    )


def test_lagrangian_matches_direct():
    """HLO-bound Lagrangian == direct eq. (3) evaluation with λ = ρu."""
    rho, theta = 5.0, 0.3
    a, b, _ = lasso_data(seed=5)
    ata, atb2, btb, _ = precompute(a, b, rho)
    n, h, m = a.shape
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((n, m)))
    u = jnp.asarray(rng.standard_normal((n, m)) * 0.1)
    z = jnp.asarray(rng.standard_normal(m))
    got = float(model.lasso_lagrangian(x, u, z, ata, atb2, btb, theta, rho))
    f = sum(
        float(jnp.sum((a[i] @ x[i] - b[i]) ** 2)) for i in range(n)
    )
    lam = rho * np.asarray(u)
    direct = (
        f
        + theta * float(jnp.sum(jnp.abs(z)))
        + float(jnp.sum(jnp.asarray(lam) * np.asarray(x - z[None, :] )))
        + 0.5 * rho * float(jnp.sum((x - z[None, :]) ** 2))
    )
    np.testing.assert_allclose(got, direct, rtol=1e-10)


def test_sync_admm_converges_with_model_fns():
    """Unquantized sync ADMM built from the exact lowered functions drives
    the relative accuracy metric below 1e-8 on a small LASSO."""
    rho, theta, s = 5.0, 0.3, 1e12  # S huge ⇒ quantization negligible
    a, b, _ = lasso_data(m=16, h=32, n=4, seed=7)
    ata, atb2, btb, minv = precompute(a, b, rho)
    n, h, m = a.shape
    x = jnp.zeros((n, m))
    u = jnp.zeros((n, m))
    z = jnp.zeros(m)
    zeros = jnp.zeros(m)
    half = jnp.full(m, 0.5)
    for _ in range(300):
        outs = [
            model.lasso_node_step(minv[i], atb2[i], z, u[i],
                                  x[i], u[i], half, half, rho, s)
            for i in range(n)
        ]
        x = jnp.stack([o[0] for o in outs])
        u = jnp.stack([o[1] for o in outs])
        z = soft_threshold_ref(jnp.mean(x + u, axis=0), theta / (rho * n))
    lag = float(model.lasso_lagrangian(x, u, z, ata, atb2, btb, theta, rho))
    # Reference optimum via many more iterations (ADMM fixed point).
    for _ in range(3000):
        outs = [
            model.lasso_node_step(minv[i], atb2[i], z, u[i],
                                  x[i], u[i], half, half, rho, s)
            for i in range(n)
        ]
        x = jnp.stack([o[0] for o in outs])
        u = jnp.stack([o[1] for o in outs])
        z = soft_threshold_ref(jnp.mean(x + u, axis=0), theta / (rho * n))
    fstar = float(model.lasso_lagrangian(x, u, z, ata, atb2, btb, theta, rho))
    assert abs(lag - fstar) / abs(fstar) < 1e-6
