//! Tiny CLI argument parser: `--key value`, `--flag`, positional args.
//!
//! Typed getters with defaults; unknown-flag detection produces a usage
//! error so typos fail loudly instead of silently running the default.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse an argv slice (without the program name). A token `--k` followed
    /// by a non-`--` token is an option; a `--k` followed by another `--` (or
    /// nothing) is a boolean flag.
    pub fn parse<I, S>(argv: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let tokens: Vec<String> = argv.into_iter().map(Into::into).collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(key) = t.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.opts.insert(key.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn note(&mut self, key: &str) {
        if !self.known.iter().any(|k| k == key) {
            self.known.push(key.to_string());
        }
    }

    pub fn str_opt(&mut self, key: &str) -> Option<String> {
        self.note(key);
        self.opts.get(key).cloned()
    }

    pub fn str(&mut self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn f64(&mut self, key: &str, default: f64) -> f64 {
        self.note(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn usize(&mut self, key: &str, default: usize) -> usize {
        self.note(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64(&mut self, key: &str, default: u64) -> u64 {
        self.note(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// A string option restricted to a fixed vocabulary; unknown values
    /// fail with the allowed list instead of silently running a default.
    pub fn choice(
        &mut self,
        key: &str,
        default: &str,
        allowed: &[&str],
    ) -> anyhow::Result<String> {
        let v = self.str(key, default);
        anyhow::ensure!(
            allowed.contains(&v.as_str()),
            "--{key} expects one of {}, got '{v}'",
            allowed.join("|")
        );
        Ok(v)
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.note(key);
        self.flags.iter().any(|f| f == key)
            || self.opts.get(key).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// After all getters ran, reject any CLI key that no getter asked about.
    pub fn finish(&self) -> anyhow::Result<()> {
        for key in self.opts.keys().chain(self.flags.iter()) {
            if !self.known.iter().any(|k| k == key) {
                anyhow::bail!(
                    "unknown option --{key}; known options: {}",
                    self.known.join(", ")
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_forms() {
        let mut a = Args::parse(vec!["run", "--iters", "500", "--q=3", "--verbose", "--tau", "3"]);
        assert_eq!(a.positional(), &["run".to_string()]);
        assert_eq!(a.usize("iters", 0), 500);
        assert_eq!(a.usize("q", 0), 3);
        assert_eq!(a.usize("tau", 0), 3);
        assert!(a.flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(Vec::<String>::new());
        assert_eq!(a.f64("rho", 500.0), 500.0);
        assert_eq!(a.str("preset", "fig3"), "fig3");
        assert!(!a.flag("baseline"));
    }

    #[test]
    fn choice_accepts_allowed_and_rejects_rest() {
        let mut a = Args::parse(vec!["--engine", "event"]);
        assert_eq!(a.choice("engine", "seq", &["seq", "event", "threaded"]).unwrap(), "event");
        let mut b = Args::parse(vec!["--engine", "warp"]);
        assert!(b.choice("engine", "seq", &["seq", "event", "threaded"]).is_err());
        let mut c = Args::parse(Vec::<String>::new());
        assert_eq!(c.choice("engine", "seq", &["seq", "event"]).unwrap(), "seq");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut a = Args::parse(vec!["--oops", "1"]);
        let _ = a.usize("iters", 10);
        assert!(a.finish().is_err());
    }

    #[test]
    #[should_panic]
    fn bad_number_panics() {
        let mut a = Args::parse(vec!["--iters", "abc"]);
        a.usize("iters", 0);
    }
}
