//! Centralized FISTA for the full LASSO
//!     minimize ‖A x − b‖² + θ‖x‖₁
//! (stacked over all nodes). Used to cross-check the F* reference optimum
//! that the accuracy metric (eq. 19) normalizes by.

use super::linalg::{norm2, sub, Mat};
use super::prox::{l1_norm, soft_threshold_in_place};

pub struct FistaResult {
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Objective ‖Ax − b‖² + θ‖x‖₁.
pub fn lasso_objective(a: &Mat, b: &[f64], theta: f64, x: &[f64]) -> f64 {
    let r = sub(&a.matvec(x), b);
    norm2(&r).powi(2) + theta * l1_norm(x)
}

/// FISTA with fixed step 1/L, L = 2·λmax(AᵀA) (f(x)=‖Ax−b‖² has ∇²=2AᵀA).
pub fn solve(a: &Mat, b: &[f64], theta: f64, tol: f64, max_iters: usize) -> FistaResult {
    let m = a.cols;
    let gram = a.gram(); // AᵀA
    let lip = 2.0 * gram.spectral_norm_sym(300) * 1.001; // small safety margin
    let step = 1.0 / lip;
    let atb = a.matvec_t(b);

    let mut x = vec![0.0; m];
    let mut y = x.clone();
    let mut t = 1.0f64;
    let mut prev_obj = lasso_objective(a, b, theta, &x);
    for k in 0..max_iters {
        // grad f(y) = 2(AᵀA y − Aᵀb)
        let gy = gram.matvec(&y);
        let mut x_new: Vec<f64> = y
            .iter()
            .zip(gy.iter().zip(&atb))
            .map(|(yi, (gi, ai))| yi - step * 2.0 * (gi - ai))
            .collect();
        soft_threshold_in_place(&mut x_new, step * theta);
        let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let momentum = (t - 1.0) / t_new;
        for ((yi, xn), xo) in y.iter_mut().zip(&x_new).zip(&x) {
            *yi = xn + momentum * (xn - xo);
        }
        x = x_new;
        t = t_new;
        if (k + 1) % 50 == 0 {
            let obj = lasso_objective(a, b, theta, &x);
            let rel = (prev_obj - obj).abs() / obj.abs().max(1e-300);
            if rel < tol {
                return FistaResult {
                    objective: obj,
                    x,
                    iterations: k + 1,
                    converged: true,
                };
            }
            prev_obj = obj;
        }
    }
    let objective = lasso_objective(a, b, theta, &x);
    FistaResult { x, objective, iterations: max_iters, converged: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn toy(seed: u64, h: usize, m: usize) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let a = Mat { rows: h, cols: m, data: rng.normal_vec(h * m, 0.0, 1.0) };
        let mut x0 = vec![0.0; m];
        for i in (0..m).step_by(5) {
            x0[i] = rng.standard_normal();
        }
        let mut b = a.matvec(&x0);
        for v in &mut b {
            *v += 0.01 * rng.standard_normal();
        }
        (a, b)
    }

    #[test]
    fn decreases_objective_monotonically_enough() {
        let (a, b) = toy(1, 60, 20);
        let start = lasso_objective(&a, &b, 0.5, &vec![0.0; 20]);
        let res = solve(&a, &b, 0.5, 1e-12, 4000);
        assert!(res.objective < start * 0.5, "start={start} end={}", res.objective);
    }

    #[test]
    fn solution_satisfies_lasso_optimality() {
        // 0 ∈ 2Aᵀ(Ax−b) + θ∂‖x‖₁
        let (a, b) = toy(2, 80, 24);
        let theta = 1.0;
        let res = solve(&a, &b, theta, 1e-14, 20_000);
        let r = sub(&a.matvec(&res.x), &b);
        let g: Vec<f64> = a.matvec_t(&r).iter().map(|v| 2.0 * v).collect();
        for (xi, gi) in res.x.iter().zip(&g) {
            if xi.abs() > 1e-9 {
                assert!((gi + theta * xi.signum()).abs() < 1e-3, "xi={xi} gi={gi}");
            } else {
                assert!(gi.abs() <= theta + 1e-3, "gi={gi}");
            }
        }
    }

    #[test]
    fn theta_zero_reduces_to_least_squares() {
        let (a, b) = toy(3, 50, 10);
        let res = solve(&a, &b, 0.0, 1e-14, 20_000);
        // normal equations: AᵀA x = Aᵀb
        let gram = a.gram();
        let atb = a.matvec_t(&b);
        let lhs = gram.matvec(&res.x);
        for (l, r) in lhs.iter().zip(&atb) {
            assert!((l - r).abs() < 1e-6, "{l} vs {r}");
        }
    }
}
