//! The paper's coordination contribution: asynchronous consensus ADMM with
//! compressed, error-fed-back exchange (QADMM, Algorithm 1).
//!
//! * [`oracle`] — the `simulate-async()` oracle (§5: two groups with
//!   selection probabilities 0.1 / 0.8).
//! * [`scheduler`] — the server's bounded-staleness bookkeeping (minimum
//!   arrivals `P`, per-node staleness counters `d_i`, forcing at τ−1).
//! * [`sim`] — the deterministic sequential simulator executing Algorithm 1
//!   verbatim (the reproducible path behind every figure).
//! * [`runner`] — the Monte-Carlo trial harness and series averaging.

pub mod oracle;
pub mod runner;
pub mod scheduler;
pub mod sim;
