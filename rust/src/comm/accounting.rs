//! Communication accounting: exact bit counters per link and direction.
//!
//! The paper's metric (eq. 20):
//!     communication bits = (total bits exchanged between nodes and server) / M
//! i.e. cumulative wire traffic normalized by the model dimension.

use crate::snapshot::codec::{Pack, Reader, Writer};

#[derive(Clone, Debug, Default)]
pub struct LinkStats {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
    pub downlink_msgs: u64,
}

/// Per-node link counters + totals for a star topology.
#[derive(Clone, Debug)]
pub struct CommAccounting {
    links: Vec<LinkStats>,
}

impl CommAccounting {
    pub fn new(n_nodes: usize) -> Self {
        Self { links: vec![LinkStats::default(); n_nodes] }
    }

    pub fn record_uplink(&mut self, node: usize, bits: u64) {
        self.links[node].uplink_bits += bits;
        self.links[node].uplink_msgs += 1;
    }

    pub fn record_downlink(&mut self, node: usize, bits: u64) {
        self.links[node].downlink_bits += bits;
        self.links[node].downlink_msgs += 1;
    }

    /// Fold a batch of uplink charges accumulated lock-free elsewhere (the
    /// deploy reactor's per-connection counters): `msgs` transmissions
    /// totalling `bits`, so per-link message counts survive batching.
    pub fn record_uplink_batch(&mut self, node: usize, msgs: u64, bits: u64) {
        self.links[node].uplink_bits += bits;
        self.links[node].uplink_msgs += msgs;
    }

    /// Downlink counterpart of [`Self::record_uplink_batch`].
    pub fn record_downlink_batch(&mut self, node: usize, msgs: u64, bits: u64) {
        self.links[node].downlink_bits += bits;
        self.links[node].downlink_msgs += msgs;
    }

    /// Downlink broadcast: the server transmits the same frame to every
    /// node; each link carries it (the paper charges both directions).
    pub fn record_broadcast(&mut self, bits: u64) {
        self.record_broadcast_to(self.links.len(), bits);
    }

    /// Broadcast to the first `k` links only. Hierarchical fan-in appends
    /// aggregator links after the n leaf links, and the ẑ broadcast goes
    /// direct server→leaf — aggregator links must not be charged for it.
    pub fn record_broadcast_to(&mut self, k: usize, bits: u64) {
        for link in self.links.iter_mut().take(k) {
            link.downlink_bits += bits;
            link.downlink_msgs += 1;
        }
    }

    pub fn link(&self, node: usize) -> &LinkStats {
        &self.links[node]
    }

    pub fn total_bits(&self) -> u64 {
        self.links.iter().map(|l| l.uplink_bits + l.downlink_bits).sum()
    }

    pub fn total_uplink_bits(&self) -> u64 {
        self.links.iter().map(|l| l.uplink_bits).sum()
    }

    pub fn total_downlink_bits(&self) -> u64 {
        self.links.iter().map(|l| l.downlink_bits).sum()
    }

    /// Eq. (20): total bits / M.
    pub fn normalized_bits(&self, m: usize) -> f64 {
        self.total_bits() as f64 / m as f64
    }

    pub fn n_nodes(&self) -> usize {
        self.links.len()
    }
}

impl Pack for LinkStats {
    fn pack(&self, w: &mut Writer) {
        w.put_u64(self.uplink_bits);
        w.put_u64(self.downlink_bits);
        w.put_u64(self.uplink_msgs);
        w.put_u64(self.downlink_msgs);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self {
            uplink_bits: r.get_u64()?,
            downlink_bits: r.get_u64()?,
            uplink_msgs: r.get_u64()?,
            downlink_msgs: r.get_u64()?,
        })
    }
}

/// Wire-bit books are run state: a resumed run must keep charging on top
/// of the interrupted totals or every bits-to-target curve restarts.
impl Pack for CommAccounting {
    fn pack(&self, w: &mut Writer) {
        self.links.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self { links: Vec::<LinkStats>::unpack(r)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_link_and_total() {
        let mut acc = CommAccounting::new(3);
        acc.record_uplink(0, 100);
        acc.record_uplink(0, 50);
        acc.record_downlink(2, 30);
        assert_eq!(acc.link(0).uplink_bits, 150);
        assert_eq!(acc.link(0).uplink_msgs, 2);
        assert_eq!(acc.link(2).downlink_bits, 30);
        assert_eq!(acc.total_bits(), 180);
    }

    #[test]
    fn broadcast_charges_every_link() {
        let mut acc = CommAccounting::new(4);
        acc.record_broadcast(10);
        assert_eq!(acc.total_downlink_bits(), 40);
        assert_eq!(acc.link(3).downlink_msgs, 1);
    }

    #[test]
    fn broadcast_to_first_k_spares_aggregator_links() {
        // 3 leaves + 2 aggregator links appended
        let mut acc = CommAccounting::new(5);
        acc.record_broadcast_to(3, 10);
        assert_eq!(acc.total_downlink_bits(), 30);
        assert_eq!(acc.link(2).downlink_msgs, 1);
        assert_eq!(acc.link(3).downlink_bits, 0);
        assert_eq!(acc.link(4).downlink_msgs, 0);
        // aggregator uplinks still accumulate per link
        acc.record_uplink(3, 7);
        assert_eq!(acc.total_bits(), 37);
    }

    /// A batched fold is indistinguishable from per-message recording —
    /// bits *and* message counts — so the reactor's amortized bookkeeping
    /// cannot drift from the per-frame ledger it replaces.
    #[test]
    fn batch_fold_matches_per_message_recording() {
        let mut a = CommAccounting::new(2);
        a.record_uplink(0, 100);
        a.record_uplink(0, 60);
        a.record_downlink(1, 40);
        let mut b = CommAccounting::new(2);
        b.record_uplink_batch(0, 2, 160);
        b.record_downlink_batch(1, 1, 40);
        assert_eq!(a.link(0).uplink_bits, b.link(0).uplink_bits);
        assert_eq!(a.link(0).uplink_msgs, b.link(0).uplink_msgs);
        assert_eq!(a.link(1).downlink_bits, b.link(1).downlink_bits);
        assert_eq!(a.link(1).downlink_msgs, b.link(1).downlink_msgs);
    }

    #[test]
    fn normalization_is_eq20() {
        let mut acc = CommAccounting::new(2);
        acc.record_uplink(0, 640);
        acc.record_downlink(1, 360);
        assert_eq!(acc.normalized_bits(100), 10.0);
    }
}
