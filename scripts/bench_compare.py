#!/usr/bin/env python3
"""Diff two BENCH_engine.json snapshots and emit a markdown delta table.

Used by the non-blocking `bench-trajectory` CI job: the committed
BENCH_engine.json (if any) is the baseline, the fresh bench run is the
current snapshot, and the table lands in the job summary so the perf
trajectory is visible per PR without gating merges on noisy runners.

Stdlib only; always exits 0 (the job is informational).

Usage:
    bench_compare.py --current BENCH_engine.json \
        [--baseline path/to/previous.json] [--summary $GITHUB_STEP_SUMMARY]
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"(bench_compare: could not read {path}: {e})", file=sys.stderr)
        return None


def fmt_delta(old, new):
    """Relative change, signed; n/a when the baseline cell is missing."""
    if old is None or not isinstance(old, (int, float)) or old == 0:
        return "n/a"
    pct = 100.0 * (new - old) / old
    arrow = "🔺" if pct > 10.0 else ("✅" if pct < -10.0 else "·")
    return f"{pct:+.1f}% {arrow}"


def index_section(records, key_fields):
    out = {}
    for rec in records or []:
        key = tuple(rec.get(k) for k in key_fields)
        out[key] = rec
    return out


def section_table(name, key_fields, metric, baseline, current):
    """Markdown table for one section, keyed on key_fields, timing `metric`."""
    cur = index_section(current.get(name), key_fields)
    base = index_section((baseline or {}).get(name), key_fields)
    if not cur:
        return f"\n_(no `{name}` records in the current snapshot)_\n"
    lines = [
        f"\n### {name}\n",
        "| " + " | ".join(key_fields) + f" | {metric} (base) | {metric} (now) | delta |",
        "|" + "---|" * (len(key_fields) + 3),
    ]
    for key, rec in cur.items():
        old = base.get(key, {}).get(metric)
        new = rec.get(metric)
        old_s = f"{old:.3f}" if isinstance(old, (int, float)) else "—"
        new_s = f"{new:.3f}" if isinstance(new, (int, float)) else "—"
        cells = [str(k) for k in key] + [old_s, new_s, fmt_delta(old, new)]
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--summary", default=None,
                    help="file to append the markdown to (e.g. $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args()

    current = load(args.current)
    if current is None:
        print("bench_compare: no current snapshot; nothing to compare")
        return
    baseline = load(args.baseline) if args.baseline else None

    out = ["## engine_scale bench trajectory"]
    if baseline is None:
        out.append(
            "\n_No committed baseline found — this snapshot becomes the "
            "first point of the trajectory._\n"
        )
    mode = "fast (QADMM_BENCH_FAST)" if current.get("fast") else "full"
    out.append(f"\nmode: {mode}\n")
    out.append(section_table(
        "sweeps", ["label", "n", "m", "tau"], "wall_s", baseline, current))
    out.append(section_table(
        "server_round", ["n", "m", "p"], "inc_round_us", baseline, current))
    text = "\n".join(out)

    print(text)
    if args.summary:
        try:
            with open(args.summary, "a") as f:
                f.write(text + "\n")
        except OSError as e:
            print(f"(bench_compare: could not append to summary: {e})",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
