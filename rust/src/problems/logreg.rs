//! L2-regularized logistic regression — the convex *inexact*-update problem
//! family the related work ([5]–[8]) simulates. Local update = K Newton-ish
//! gradient steps on the prox-augmented local loss (native f64), so this
//! exercises the inexact path without the NN artifacts.
//!
//! ```text
//!     minimize Σᵢ Σ_j log(1 + exp(−y_j aᵢⱼᵀx)) + (γ/2)‖x‖²
//! ```
//!
//! The ridge term is carried by the consensus prox (h = γ/2‖·‖²  ⇒
//! z = ρN/(γ+ρN) · mean(x̂+û)).
//!
//! The update is pure math over per-node data — no RNG draws — so the
//! batch fan-out runs on the shared worker pool
//! ([`crate::problems::fan_out_batch`]), bit-identical to the sequential
//! order for any pool size (the engine-parity contract relies on this).

use super::{fan_out_batch, Arena, EvalMetrics, LocalUpdateItem, Problem};
use crate::solver::linalg::{dot, Mat};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug)]
pub struct LogRegConfig {
    pub m: usize,
    pub h: usize,
    pub n: usize,
    pub rho: f64,
    /// ridge coefficient γ
    pub gamma: f64,
    /// inner gradient steps per local update
    pub k_steps: usize,
    /// inner step size
    pub lr: f64,
}

/// Σ_j log(1 + exp(−y_j aᵀx)) for one node's data. Free function so the
/// sequential path and the worker-pool fan-out share one body.
fn node_nll(a: &Mat, y: &[f64], x: &[f64]) -> f64 {
    let margins = a.matvec(x);
    margins
        .iter()
        .zip(y)
        .map(|(&mgn, &yj)| {
            let t = -yj * mgn;
            // stable log1p(exp(t))
            if t > 30.0 { t } else { (1.0 + t.exp()).ln() }
        })
        .sum()
}

fn node_grad(a: &Mat, y: &[f64], x: &[f64]) -> Vec<f64> {
    let margins = a.matvec(x);
    let w: Vec<f64> = margins
        .iter()
        .zip(y)
        .map(|(&mgn, &yj)| -yj / (1.0 + (yj * mgn).exp()))
        .collect();
    a.matvec_t(&w)
}

/// Eq. (9a) inexact solve: K gradient steps on f_i(x) + ρ/2‖x − ẑ + u‖²
/// with a 1/(L̂+ρ)-ish fixed step, from `x_prev`. Deterministic (no RNG).
fn inexact_primal(
    a: &Mat,
    y: &[f64],
    cfg: &LogRegConfig,
    zhat: &[f64],
    u: &[f64],
    x_prev: &[f64],
) -> (Vec<f64>, f64) {
    let rho = cfg.rho;
    let mut x = x_prev.to_vec();
    for _ in 0..cfg.k_steps {
        let mut g = node_grad(a, y, &x);
        for j in 0..cfg.m {
            g[j] += rho * (x[j] - zhat[j] + u[j]);
        }
        for j in 0..cfg.m {
            x[j] -= cfg.lr * g[j];
        }
    }
    let loss = node_nll(a, y, &x);
    (x, loss)
}

pub struct LogRegProblem {
    pub cfg: LogRegConfig,
    a: Vec<Mat>,        // features per node [h × m]
    y: Vec<Vec<f64>>,   // labels ±1
    fstar: Option<f64>,
    pub x_true: Vec<f64>,
}

impl LogRegProblem {
    pub fn generate(cfg: LogRegConfig, rng: &mut Pcg64) -> anyhow::Result<Self> {
        anyhow::ensure!(cfg.m > 0 && cfg.h > 0 && cfg.n > 0 && cfg.k_steps > 0);
        let x_true = rng.normal_vec(cfg.m, 0.0, 1.0);
        let mut a = Vec::with_capacity(cfg.n);
        let mut y = Vec::with_capacity(cfg.n);
        for _ in 0..cfg.n {
            let ai = Mat { rows: cfg.h, cols: cfg.m, data: rng.normal_vec(cfg.h * cfg.m, 0.0, 1.0) };
            let margins = ai.matvec(&x_true);
            // labels from the logistic model (adds irreducible noise)
            let yi = margins
                .iter()
                .map(|&mgn| {
                    let p = 1.0 / (1.0 + (-mgn).exp());
                    if rng.uniform_f64() < p { 1.0 } else { -1.0 }
                })
                .collect();
            a.push(ai);
            y.push(yi);
        }
        Ok(Self { cfg, a, y, fstar: None, x_true })
    }

    /// Σ_j log(1 + exp(−y_j aᵀx)) for one node.
    fn local_nll(&self, node: usize, x: &[f64]) -> f64 {
        node_nll(&self.a[node], &self.y[node], x)
    }

    fn local_grad(&self, node: usize, x: &[f64]) -> Vec<f64> {
        node_grad(&self.a[node], &self.y[node], x)
    }

    /// Global objective at consensus point z.
    pub fn objective(&self, z: &[f64]) -> f64 {
        let nll: f64 = (0..self.cfg.n).map(|i| self.local_nll(i, z)).sum();
        nll + 0.5 * self.cfg.gamma * dot(z, z)
    }

    /// Augmented Lagrangian (eq. 4 with h = γ/2‖·‖²) over the n×m iterate
    /// arenas.
    pub fn lagrangian(&self, x: &Arena, u: &Arena, z: &[f64]) -> f64 {
        let mut total = 0.5 * self.cfg.gamma * dot(z, z);
        for i in 0..self.cfg.n {
            let (xi, ui) = (x.row(i), u.row(i));
            total += self.local_nll(i, xi);
            for j in 0..self.cfg.m {
                let r = xi[j] - z[j] + ui[j];
                total += 0.5 * self.cfg.rho * (r * r - ui[j] * ui[j]);
            }
        }
        total
    }

    /// High-precision F* via long synchronous exact-ish ADMM (many inner
    /// steps). Cached.
    pub fn reference_optimum(&mut self, outer: usize) -> f64 {
        if let Some(f) = self.fstar {
            return f;
        }
        let (m, n) = (self.cfg.m, self.cfg.n);
        let save = self.cfg.k_steps;
        let mut x = vec![vec![0.0; m]; n];
        let mut u = vec![vec![0.0; m]; n];
        let mut z = vec![0.0; m];
        self.cfg.k_steps = 200; // near-exact inner solves for the reference
        let mut rng = Pcg64::seed_from_u64(0);
        for _ in 0..outer {
            for i in 0..n {
                let (xi, _) = self.local_update(i, &z, &u[i], &x[i], &mut rng).unwrap();
                x[i] = xi;
                for j in 0..m {
                    u[i][j] += x[i][j] - z[j];
                }
            }
            let xs = x.clone();
            let us = u.clone();
            z = self.consensus(&xs, &us).unwrap();
        }
        self.cfg.k_steps = save;
        let f = self.lagrangian(&Arena::from_rows(&x), &Arena::from_rows(&u), &z);
        self.fstar = Some(f);
        f
    }
}

impl Problem for LogRegProblem {
    fn dim(&self) -> usize {
        self.cfg.m
    }

    fn n_nodes(&self) -> usize {
        self.cfg.n
    }

    fn name(&self) -> String {
        format!(
            "logreg(m={},h={},n={},rho={},gamma={},k={})",
            self.cfg.m, self.cfg.h, self.cfg.n, self.cfg.rho, self.cfg.gamma, self.cfg.k_steps
        )
    }

    fn init_x(&mut self, _rng: &mut Pcg64) -> Vec<f64> {
        vec![0.0; self.cfg.m]
    }

    /// Inexact primal update: K gradient steps on
    /// f_i(x) + ρ/2‖x − ẑ + u‖² with a 1/(L̂+ρ)-ish fixed step.
    fn local_update(
        &mut self,
        node: usize,
        zhat: &[f64],
        u: &[f64],
        x_prev: &[f64],
        _rng: &mut Pcg64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        Ok(inexact_primal(&self.a[node], &self.y[node], &self.cfg, zhat, u, x_prev))
    }

    /// Worker-pool fan-out over the shared [`fan_out_batch`] helper (the
    /// same pool native LASSO uses): the K-step gradient loop is pure math
    /// over per-node (Aᵢ, yᵢ), so chunks run on scoped threads and merge in
    /// item order — bit-identical to sequential for any pool size.
    fn local_update_batch(
        &mut self,
        items: &mut [LocalUpdateItem<'_>],
    ) -> anyhow::Result<Vec<(Vec<f64>, f64)>> {
        let (a, y, cfg) = (&self.a, &self.y, &self.cfg);
        Ok(fan_out_batch(items, |it: &LocalUpdateItem<'_>| {
            inexact_primal(&a[it.node], &y[it.node], cfg, it.zhat, it.u, it.x_prev)
        }))
    }

    /// prox of γ/2‖·‖²: z = ρN/(γ + ρN) · mean(x̂ + û).
    fn consensus(&mut self, xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        let (m, n) = (self.cfg.m, xhat.len());
        let mut sum = vec![0.0; m];
        for i in 0..n {
            for j in 0..m {
                sum[j] += xhat[i][j] + uhat[i][j];
            }
        }
        self.consensus_from_sum(&sum, n)
    }

    /// The shrunk mean from the running sum: z = shrink · (s/n), O(m).
    fn consensus_from_sum(&mut self, sum: &[f64], n_nodes: usize) -> anyhow::Result<Vec<f64>> {
        let (rho, gamma) = (self.cfg.rho, self.cfg.gamma);
        let n = n_nodes as f64;
        let shrink = rho * n / (gamma + rho * n);
        Ok(sum.iter().map(|s| shrink * (s / n)).collect())
    }

    fn evaluate(&mut self, x: &Arena, u: &Arena, z: &[f64]) -> anyhow::Result<EvalMetrics> {
        let fstar = self.reference_optimum(400);
        let lag = self.lagrangian(x, u, z);
        Ok(EvalMetrics {
            accuracy: (lag - fstar).abs() / fstar.abs().max(f64::MIN_POSITIVE),
            test_acc: f64::NAN,
            loss: lag,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::runner;
    use crate::config::presets;

    fn small() -> LogRegConfig {
        LogRegConfig { m: 12, h: 60, n: 4, rho: 2.0, gamma: 1.0, k_steps: 15, lr: 0.02 }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Pcg64::seed_from_u64(1);
        let p = LogRegProblem::generate(small(), &mut rng).unwrap();
        let x = rng.normal_vec(12, 0.0, 0.5);
        let g = p.local_grad(0, &x);
        let eps = 1e-6;
        for j in [0usize, 5, 11] {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (p.local_nll(0, &xp) - p.local_nll(0, &xm)) / (2.0 * eps);
            assert!((fd - g[j]).abs() < 1e-4, "j={j}: fd={fd} g={}", g[j]);
        }
    }

    #[test]
    fn consensus_is_shrunk_mean() {
        let mut rng = Pcg64::seed_from_u64(2);
        let mut p = LogRegProblem::generate(small(), &mut rng).unwrap();
        let xhat: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(12, 0.0, 1.0)).collect();
        let uhat: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(12, 0.0, 1.0)).collect();
        let z = p.consensus(&xhat, &uhat).unwrap();
        let shrink = 2.0 * 4.0 / (1.0 + 2.0 * 4.0);
        for j in 0..12 {
            let mean =
                (0..4).map(|i| xhat[i][j] + uhat[i][j]).sum::<f64>() / 4.0;
            assert!((z[j] - shrink * mean).abs() < 1e-12);
        }
    }

    /// The worker-pool fan-out must be bit-identical to node-by-node calls
    /// (the engine parity contract leans on this for the inexact family).
    #[test]
    fn batch_update_matches_sequential() {
        let mut rng = Pcg64::seed_from_u64(6);
        let mut p = LogRegProblem::generate(small(), &mut rng).unwrap();
        let zhat = rng.normal_vec(12, 0.0, 1.0);
        let us: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(12, 0.0, 0.1)).collect();
        let x_prev = rng.normal_vec(12, 0.0, 0.3);
        let seq: Vec<(Vec<f64>, f64)> = (0..4)
            .map(|i| p.local_update(i, &zhat, &us[i], &x_prev, &mut rng).unwrap())
            .collect();
        let mut rngs: Vec<Pcg64> = (0..4).map(|i| Pcg64::seed_from_u64(i as u64)).collect();
        let mut items: Vec<LocalUpdateItem> = rngs
            .iter_mut()
            .enumerate()
            .map(|(i, rng)| LocalUpdateItem {
                node: i,
                zhat: &zhat,
                u: &us[i],
                x_prev: &x_prev,
                rng,
            })
            .collect();
        let batch = p.local_update_batch(&mut items).unwrap();
        assert_eq!(seq, batch);
    }

    #[test]
    fn qadmm_converges_on_logreg() {
        let mut cfg = presets::ci_lasso(); // reuse knobs; problem comes from factory
        cfg.name = "ci-logreg".into();
        cfg.iters = 250;
        cfg.mc_trials = 1;
        let lcfg = small();
        let mut factory: Box<runner::ProblemFactory> =
            Box::new(move |_seed, rng: &mut Pcg64| {
                Ok(Box::new(LogRegProblem::generate(lcfg, rng)?) as Box<dyn Problem>)
            });
        let res = runner::run_mc(&cfg, factory.as_mut()).unwrap();
        let acc = *res.mean_accuracy.last().unwrap();
        assert!(acc < 1e-4, "final accuracy {acc}");
    }

    #[test]
    fn quantized_matches_baseline_quality_with_fewer_bits() {
        let mut cfg = presets::ci_lasso();
        cfg.name = "ci-logreg-cmp".into();
        cfg.iters = 250;
        cfg.mc_trials = 1;
        let lcfg = small();
        let run = |cfg: &crate::config::ExperimentConfig| {
            let mut factory: Box<runner::ProblemFactory> =
                Box::new(move |_seed, rng: &mut Pcg64| {
                    Ok(Box::new(LogRegProblem::generate(lcfg, rng)?) as Box<dyn Problem>)
                });
            runner::run_mc(cfg, factory.as_mut()).unwrap()
        };
        let q = run(&cfg);
        let mut base = cfg.clone();
        base.compressor = crate::compress::CompressorKind::Identity32;
        let b = run(&base);
        let qa = *q.mean_accuracy.last().unwrap();
        let ba = *b.mean_accuracy.last().unwrap();
        assert!(qa < 1e-4 && ba < 1e-4, "q={qa} b={ba}");
        let qbits = *q.mean_comm_bits.last().unwrap();
        let bbits = *b.mean_comm_bits.last().unwrap();
        // m = 12 is tiny, so frame headers (14 B + norm) eat into the 3/32
        // asymptotic ratio — still expect a ≥2x reduction.
        assert!(qbits < 0.5 * bbits, "bits: q={qbits} vs b={bbits}");
    }
}
