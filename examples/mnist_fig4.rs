//! Reproduce **Figure 4** (§5.2): federated training of the paper's 6-layer
//! CNN (M = 246,026) on (synthetic-)MNIST with inexact QADMM — 10 Adam
//! steps of batch 64 per outer iteration, N = 3, q = 3, τ = 3 — against the
//! unquantized async-ADMM baseline. Test accuracy vs iterations and vs
//! communication bits.
//!
//!     cargo run --release --example mnist_fig4 -- [--iters 60] [--trials 2]
//!         [--arch cnn|mlp] [--train 3000] [--test 1024] [--quick]
//!
//! `--quick` switches to the MLP variant for a fast smoke run. If real
//! MNIST IDX files exist under `data/mnist/`, they are used; otherwise the
//! deterministic synthetic corpus is generated (see DESIGN.md §3).

use qadmm::config::presets;
use qadmm::exp::fig4::{self, Fig4Options};
use qadmm::problems::nn::NnArch;
use qadmm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let quick = args.flag("quick");
    let arch = match args.str("arch", if quick { "mlp" } else { "cnn" }).as_str() {
        "cnn" => NnArch::Cnn,
        "mlp" => NnArch::Mlp,
        other => anyhow::bail!("unknown arch '{other}'"),
    };
    let opts = Fig4Options {
        arch,
        iters: args.usize("iters", if quick { 25 } else { presets::fig4().iters }),
        mc_trials: args.usize("trials", if quick { 1 } else { presets::fig4().mc_trials }),
        n_train: args.usize("train", if quick { 1500 } else { 3000 }),
        n_test: args.usize("test", if quick { 512 } else { 1024 }),
        target: args.f64("target", if quick { 0.85 } else { 0.95 }),
        out_dir: args.str("out", "out").into(),
        artifact_dir: args.str("artifacts", "artifacts").into(),
        data_dir: args.str("data", "data/mnist").into(),
    };
    args.finish()?;

    println!(
        "fig4: arch={:?} iters={} trials={} train={} test={}",
        opts.arch, opts.iters, opts.mc_trials, opts.n_train, opts.n_test
    );
    let summary = fig4::run(&opts)?;
    for s in &summary.series {
        println!("--- {} (test-accuracy milestones) ---", s.label);
        print!("{}", qadmm::exp::milestones(&s.mean_recorder(), |r| r.test_acc));
    }
    println!();
    for h in &summary.headline {
        println!("{h}");
    }
    println!("CSV series in {}", opts.out_dir.display());
    Ok(())
}
