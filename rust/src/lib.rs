//! # QADMM — Communication-Efficient Distributed Asynchronous ADMM
//!
//! Rust implementation of the paper's system: an asynchronous consensus-ADMM
//! coordinator (server + nodes, star topology) where every uplink and
//! downlink exchange is compressed with a stochastic multi-level quantizer
//! plus error feedback, so only quantized *deltas* of the iterates travel on
//! the wire (~90% fewer bits at equal convergence).
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — node/server state machines, the async scheduler
//!   (minimum-arrivals threshold `P`, bounded staleness `τ`), the wire codec
//!   and bit accounting, experiment harnesses, metrics and the CLI.
//! * **L2/L1 (python, build-time only)** — JAX graphs + Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt`, executed here via PJRT
//!   ([`runtime`]). Python is never on the request path.
//!
//! Three engines execute Algorithm 1 (select with `--engine`):
//! * [`admm::sim`] (`seq`) — the sequential round-based simulator, the
//!   bit-exact reference behind every figure;
//! * [`admm::engine`] (`event`) — the event-driven virtual-time engine for
//!   1000+-node asynchrony studies (per-link compute/uplink/downlink
//!   delays + clock drift, downlink-delayed ẑ mirrors, P-arrival trigger,
//!   τ−1 force-wait) with no wall-clock sleeps; identical to `seq`
//!   bit-for-bit at zero link delay with the identity compressor;
//! * [`coordinator`] (`threaded`) — real server/node threads over the
//!   accounted star network, for deployment-shaped runs and fault
//!   injection.
//!
//! The library is fully self-contained: the build environment exposes only
//! the `xla` crate's dependency closure, so the JSON, RNG, CLI, bench and
//! property-test substrates are implemented in-tree ([`util`]).

pub mod admm;
pub mod bench_harness;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod deploy;
pub mod exp;
pub mod metrics;
pub mod problems;
pub mod runtime;
pub mod snapshot;
pub mod solver;
pub mod topology;
pub mod util;

pub use compress::{Compressor, CompressorKind};
pub use config::ExperimentConfig;
