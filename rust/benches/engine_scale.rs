//! Event-engine scaling sweep: n ∈ {16, 128, 1024} nodes, a τ ×
//! downlink-delay grid at n ∈ {256, 1024}, the `server_round` section
//! comparing the old O(n·m) bank-sweep fire against the incremental
//! O(|A|·m) accumulator path at n ∈ {256, 1024, 4096} × P ∈ {n/8, n/2, n},
//! and the `server_round_nn` section at NN-scale m ∈ {10^5, 10^6}
//! comparing the fused O(k) sparse frame fold against the retired
//! materialize-then-fold path and the coordinate-sharded dense fire
//! against the serial kernel. The `scale_xl` section drives the whole
//! engine at fleet sizes n ∈ {10^5, 10^6} (small m) and *asserts* the
//! peak-RSS budget — the million-node acceptance bar: calendar-queue
//! timeline, quantized-at-rest banks, shared mirror window, sampled
//! metrics, all under a flat memory ceiling. The `deploy_loadgen` section
//! drives the sharded reactor socket server with N ∈ {64, 256, 512}
//! in-process UDS workers, recording rounds/s, the io-thread count, and
//! p50/p99 round latency, with the exact byte reconciliation re-asserted
//! under load.
//!
//! The headline configuration is the acceptance bar for the virtual-time
//! engine: **n = 1024 nodes, m = 10240-dim LASSO, 200 consensus rounds,
//! heterogeneous straggler latency — in seconds of wall-clock, not hours**
//! (the threaded runtime would sleep through every injected delay; the
//! sequential simulator has no notion of stragglers at all). Feasible
//! because the LASSO Woodbury solver never forms an m×m inverse (h ≪ m)
//! and the per-node fan-out runs on the worker pool.
//!
//! The downlink grid exercises the per-link decomposition end to end:
//! delayed ẑ delivery multiplies `DownlinkArrive` events and fragments the
//! dispatch batches, which is exactly the regime the mirror bookkeeping
//! has to keep cheap.
//!
//! Every section's numbers are also written as machine-readable JSON to
//! `BENCH_engine.json` at the repo root, so the perf trajectory is
//! recorded run over run.
//!
//! `QADMM_BENCH_FAST=1` shrinks all sweeps for CI smoke runs.

use qadmm::admm::engine::EventEngine;
use qadmm::admm::sim::TrialRngs;
use qadmm::comm::latency::LatencyModel;
use qadmm::comm::profile::LinkConfig;
use qadmm::compress::CompressorKind;
use qadmm::config::{presets, EngineKind, ExperimentConfig, OracleConfig, ProblemKind};
use qadmm::problems::accumulator::ConsensusAccumulator;
use qadmm::problems::lasso::{LassoConfig, LassoProblem};
use qadmm::problems::{Arena, EvalMetrics, Problem};
use qadmm::solver::prox;
use qadmm::util::json::Json;
use qadmm::util::rng::Pcg64;
use qadmm::util::timer::{fmt_count, Stopwatch};

struct Sweep {
    n: usize,
    m: usize,
    h: usize,
    rounds: usize,
    tau: usize,
    link: LinkConfig,
    label: &'static str,
}

/// The straggler mixture of the original scaling sweep, split across the
/// compute and uplink legs (virtual seconds).
fn straggler_link() -> LinkConfig {
    let mix = LatencyModel::Mixture { fast: 0.002, slow: 0.25, p_slow: 0.15 };
    LinkConfig { compute: mix, uplink: mix, downlink: LatencyModel::None, clock_drift: 0.0 }
}

fn base_cfg(s: &Sweep) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    cfg.name = format!("engine-scale-n{}-{}", s.n, s.label);
    cfg.problem = ProblemKind::Lasso { m: s.m, h: s.h, n: s.n, rho: 50.0, theta: 0.1 };
    cfg.engine = EngineKind::Event;
    cfg.tau = s.tau;
    cfg.p_min = (s.n / 4).max(1);
    cfg.iters = s.rounds;
    cfg.mc_trials = 1;
    cfg.eval_every = s.rounds; // one final eval; per-round eval is O(n·h·m)
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
    // Injected delays in *virtual* seconds: a threaded run would sleep
    // ~rounds × slow-tail of real time; the engine only does arithmetic.
    cfg.link = s.link;
    cfg
}

fn run_sweep(s: &Sweep) -> anyhow::Result<Json> {
    let cfg = base_cfg(s);
    let gen_clock = Stopwatch::new();
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut problem = LassoProblem::generate(
        LassoConfig { m: s.m, h: s.h, n: s.n, rho: 50.0, theta: 0.1 },
        &mut rngs.data,
    )?;
    // The accuracy metric needs F*, which costs thousands of reference
    // rounds — irrelevant for a throughput bench.
    problem.set_reference_optimum(1.0);
    let gen_s = gen_clock.elapsed_secs();

    let clock = Stopwatch::new();
    let mut engine = EventEngine::new(&cfg, &mut problem, rngs)?;
    for _ in 0..s.rounds {
        engine.step_round()?;
    }
    let wall = clock.elapsed_secs();
    let stats = engine.stats();
    println!(
        "{:24} n={:5} m={:6} tau={:2} rounds={:4}  wall {:7.2}s (gen {:5.2}s)  \
         virtual {:8.2}s  speedup {:>9}x  events/s {:>9}  dispatches {}",
        s.label,
        s.n,
        s.m,
        s.tau,
        s.rounds,
        wall,
        gen_s,
        stats.virtual_time,
        fmt_count(stats.virtual_time / wall.max(1e-9)),
        fmt_count(stats.events as f64 / wall.max(1e-9)),
        stats.dispatches,
    );
    if s.n >= 1024 && wall >= 10.0 {
        println!("  !! acceptance bar missed: n={} took {wall:.2}s (target < 10s)", s.n);
    }
    Ok(Json::obj(vec![
        ("label", Json::Str(s.label.into())),
        ("n", Json::Num(s.n as f64)),
        ("m", Json::Num(s.m as f64)),
        ("tau", Json::Num(s.tau as f64)),
        ("rounds", Json::Num(s.rounds as f64)),
        ("wall_s", Json::Num(wall)),
        ("gen_s", Json::Num(gen_s)),
        ("virtual_s", Json::Num(stats.virtual_time)),
        ("events", Json::Num(stats.events as f64)),
        ("dispatches", Json::Num(stats.dispatches as f64)),
    ]))
}

fn scale_sweep(n: usize, m: usize, h: usize, rounds: usize) -> Sweep {
    Sweep { n, m, h, rounds, tau: 4, link: straggler_link(), label: "scale" }
}

// ---- scale_xl: million-node fleets, O(active) memory ------------------------

/// One extra-large fleet cell (n up to 10^6, small m so per-node data stays
/// honest): the full engine — calendar-queue timeline, quantized-at-rest
/// banks, shared mirror window, `--metrics-sample` evaluation — driven for
/// a few consensus rounds with the straggler mixture. Asserts the peak-RSS
/// budget (the acceptance bar of the million-node work: memory stays flat
/// beyond the inherent iterate arenas + the active set, so a regression
/// back to dense per-node banks or per-node downlink FIFOs fails loudly)
/// and reports the new queue high-water / scheduled-event counters.
fn scale_xl_cell(n: usize, rounds: usize) -> anyhow::Result<Json> {
    let (m, h) = (8usize, 4usize);
    let sweep =
        Sweep { n, m, h, rounds, tau: 4, link: straggler_link(), label: "scale_xl" };
    let mut cfg = base_cfg(&sweep);
    cfg.name = format!("engine-scale-xl-n{n}");
    // full-fleet evaluation is O(n·h·m) per eval — the sampled Lagrangian
    // (64 nodes, rescaled) is the point of --metrics-sample
    cfg.metrics_sample = 64;

    let gen_clock = Stopwatch::new();
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut problem = LassoProblem::generate(
        LassoConfig { m, h, n, rho: 50.0, theta: 0.1 },
        &mut rngs.data,
    )?;
    problem.set_reference_optimum(1.0);
    let gen_s = gen_clock.elapsed_secs();

    let clock = Stopwatch::new();
    let mut engine = EventEngine::new(&cfg, &mut problem, rngs)?;
    for _ in 0..rounds {
        engine.step_round()?;
    }
    let wall = clock.elapsed_secs();
    let stats = engine.stats();
    let peak_rss_mb = qadmm::util::mem::peak_rss_mb();
    println!(
        "scale_xl                n={n:8} m={m:3} rounds={rounds:2}  wall {wall:7.2}s \
         (gen {gen_s:5.2}s)  peak RSS {}  queue peak {}  events {}",
        peak_rss_mb.map_or("n/a".into(), |mb| format!("{mb:7.0} MiB")),
        fmt_count(stats.queue_peak as f64),
        fmt_count(stats.events_scheduled as f64),
    );
    // VmHWM is process-wide (earlier sections count toward it), so the
    // budgets leave headroom — but any O(n·m)-per-round leak or a return
    // to dense per-node state at n = 10^6 overshoots them by an order of
    // magnitude.
    if let Some(mb) = peak_rss_mb {
        let budget_mb = if n >= 1_000_000 { 4096.0 } else { 1536.0 };
        anyhow::ensure!(
            mb < budget_mb,
            "peak RSS {mb:.0} MiB exceeds the {budget_mb:.0} MiB budget at n = {n}"
        );
    }
    // the queue must stay O(n), not O(rounds·n): downlink arrivals drain
    // before the next broadcast wave under this link profile (≤1 compute,
    // ≤1 uplink in flight per node + one broadcast wave + timer slack)
    anyhow::ensure!(
        stats.queue_peak <= 4 * n + 64,
        "queue peak {} is not O(n) at n = {n}",
        stats.queue_peak
    );
    Ok(Json::obj(vec![
        ("label", Json::Str("scale_xl".into())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("tau", Json::Num(4.0)),
        ("rounds", Json::Num(rounds as f64)),
        ("wall_s", Json::Num(wall)),
        ("gen_s", Json::Num(gen_s)),
        ("virtual_s", Json::Num(stats.virtual_time)),
        ("events", Json::Num(stats.events as f64)),
        ("dispatches", Json::Num(stats.dispatches as f64)),
        ("queue_peak", Json::Num(stats.queue_peak as f64)),
        ("events_scheduled", Json::Num(stats.events_scheduled as f64)),
        (
            "peak_rss_mb",
            peak_rss_mb.map_or(Json::Null, Json::Num),
        ),
    ]))
}

// ---- server_round: old O(n·m) fire vs incremental O(|A|·m) -----------------

/// Server-side view of the LASSO consensus (soft-thresholded mean) with no
/// node data attached — isolates the fire cost from problem generation so
/// the section can run at n = 4096 in milliseconds.
struct ProxMean {
    m: usize,
    n: usize,
}

impl Problem for ProxMean {
    fn dim(&self) -> usize {
        self.m
    }

    fn n_nodes(&self) -> usize {
        self.n
    }

    fn name(&self) -> String {
        format!("prox-mean(m={},n={})", self.m, self.n)
    }

    fn init_x(&mut self, _rng: &mut Pcg64) -> Vec<f64> {
        vec![0.0; self.m]
    }

    fn local_update(
        &mut self,
        _node: usize,
        _zhat: &[f64],
        _u: &[f64],
        _x_prev: &[f64],
        _rng: &mut Pcg64,
    ) -> anyhow::Result<(Vec<f64>, f64)> {
        anyhow::bail!("server-side bench problem has no local update")
    }

    /// The old fire: O(n·m) sweep over the banks.
    fn consensus(&mut self, xhat: &[Vec<f64>], uhat: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        let mut v = vec![0.0; self.m];
        for (xi, ui) in xhat.iter().zip(uhat) {
            for j in 0..self.m {
                v[j] += xi[j] + ui[j];
            }
        }
        let n = self.n as f64;
        for vj in &mut v {
            *vj /= n;
        }
        prox::soft_threshold_in_place(&mut v, 0.1 / (50.0 * n));
        Ok(v)
    }

    /// The incremental fire: O(m) prox of the running sum.
    fn consensus_from_sum(&mut self, sum: &[f64], n_nodes: usize) -> anyhow::Result<Vec<f64>> {
        let n = n_nodes as f64;
        let mut v: Vec<f64> = sum.iter().map(|s| s / n).collect();
        prox::soft_threshold_in_place(&mut v, 0.1 / (50.0 * n));
        Ok(v)
    }

    fn evaluate(&mut self, _x: &Arena, _u: &Arena, _z: &[f64]) -> anyhow::Result<EvalMetrics> {
        anyhow::bail!("server-side bench problem has no metrics")
    }
}

/// Time one (n, P) cell: the seed's fire (copy banks into the persistent
/// consensus-input buffers + `consensus`) against the incremental round
/// (P folds at arrival time + `consensus_from_sum` at fire time).
fn server_round_cell(n: usize, m: usize, p: usize, reps: usize) -> anyhow::Result<Json> {
    let mut rng = Pcg64::seed_from_u64(0x5eed ^ n as u64);
    let mut problem = ProxMean { m, n };
    let xhat: Vec<Vec<f64>> = (0..n).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();
    let uhat: Vec<Vec<f64>> = (0..n).map(|_| rng.normal_vec(m, 0.0, 0.1)).collect();
    // one arrival batch worth of dequantized deltas, reused every rep
    let deltas: Vec<(Vec<f64>, Vec<f64>)> = (0..p)
        .map(|_| (rng.normal_vec(m, 0.0, 0.01), rng.normal_vec(m, 0.0, 0.01)))
        .collect();

    // old path: the seed refreshed these n×m buffers from the banks at
    // every fire, then swept them in `consensus`
    let mut xs_buf: Vec<Vec<f64>> = vec![vec![0.0; m]; n];
    let mut us_buf: Vec<Vec<f64>> = vec![vec![0.0; m]; n];
    let clock = Stopwatch::new();
    let mut sink = 0.0;
    for _ in 0..reps {
        for (buf, t) in xs_buf.iter_mut().zip(&xhat) {
            buf.copy_from_slice(t);
        }
        for (buf, t) in us_buf.iter_mut().zip(&uhat) {
            buf.copy_from_slice(t);
        }
        let z = problem.consensus(&xs_buf, &us_buf)?;
        sink += z[0];
    }
    let old_fire_us = clock.elapsed_secs() * 1e6 / reps as f64;

    // incremental path, whole round: P arrival folds + the O(m) fire
    let mut acc = ConsensusAccumulator::new(m, 0);
    acc.refresh(xhat.iter().zip(&uhat).map(|(x, u)| (x.as_slice(), u.as_slice())));
    let clock = Stopwatch::new();
    for _ in 0..reps {
        for (dx, du) in &deltas {
            acc.fold(dx, du);
        }
        let z = problem.consensus_from_sum(acc.sum(), n)?;
        sink += z[0];
    }
    let inc_round_us = clock.elapsed_secs() * 1e6 / reps as f64;

    // fire alone (the folds happen at arrival time, spread across the
    // round — this is what the server blocks on)
    let clock = Stopwatch::new();
    for _ in 0..reps {
        let z = problem.consensus_from_sum(acc.sum(), n)?;
        sink += z[0];
    }
    let inc_fire_us = clock.elapsed_secs() * 1e6 / reps as f64;
    std::hint::black_box(sink);

    let speedup_round = old_fire_us / inc_round_us.max(1e-9);
    let speedup_fire = old_fire_us / inc_fire_us.max(1e-9);
    println!(
        "server_round            n={n:5} m={m:6} P={p:5}  old {old_fire_us:9.1}us  \
         inc-round {inc_round_us:9.1}us  inc-fire {inc_fire_us:9.1}us  \
         speedup {speedup_round:6.1}x (fire-only {speedup_fire:.0}x)"
    );
    Ok(Json::obj(vec![
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("p", Json::Num(p as f64)),
        ("reps", Json::Num(reps as f64)),
        ("old_fire_us", Json::Num(old_fire_us)),
        ("inc_round_us", Json::Num(inc_round_us)),
        ("inc_fire_us", Json::Num(inc_fire_us)),
        ("speedup_round", Json::Num(speedup_round)),
        ("speedup_fire", Json::Num(speedup_fire)),
    ]))
}

// ---- server_round_nn: NN-scale fused sparse folds + sharded fires ----------

/// NN-scale server hot path (m up to 10^6): the fused O(k) sparse frame
/// fold against the retired materialize-then-dense-fold path (which paid an
/// O(m) allocation + traversal per arrival regardless of k), and the
/// coordinate-sharded dense fire kernel against the serial one. The fused
/// column should be flat in m at fixed k; the sharded fire should win at
/// m = 10^6 where the dense O(m) work amortizes the thread fan-out.
fn server_round_nn_cell(
    n: usize,
    m: usize,
    p: usize,
    k: usize,
    reps: usize,
) -> anyhow::Result<Json> {
    use qadmm::compress::{wire, Compressed};
    use qadmm::problems::accumulator::{auto_shards, KahanVec};

    let mut rng = Pcg64::seed_from_u64(0x4e4e ^ m as u64);
    let mut problem = ProxMean { m, n };
    // one arrival batch of top-k-shaped wire frames (k nonzeros each),
    // reused every rep — exactly what a sparse-compressor fleet sends
    let make_frame = |rng: &mut Pcg64| {
        let mut idx: Vec<usize> = (0..k).map(|_| rng.gen_range(m)).collect();
        idx.sort_unstable();
        idx.dedup();
        let entries: Vec<(usize, f64)> =
            idx.iter().map(|&i| (i, rng.standard_normal() * 0.01)).collect();
        Compressed { wire: wire::encode_topk(m, &entries) }
    };
    let frames: Vec<(Compressed, Compressed)> =
        (0..p).map(|_| (make_frame(&mut rng), make_frame(&mut rng))).collect();
    let mut sink = 0.0;

    // fused round: P sparse frame folds (O(k) each) + the O(m) fire
    let mut acc = ConsensusAccumulator::new(m, 0);
    let clock = Stopwatch::new();
    for _ in 0..reps {
        for (cx, cu) in &frames {
            acc.fold_frames(cx, cu)?;
        }
        let z = problem.consensus_from_sum(acc.sum(), n)?;
        sink += z[0];
    }
    let fused_round_us = clock.elapsed_secs() * 1e6 / reps as f64;

    // retired path: materialize each frame dense, then dense-fold
    let mut acc = ConsensusAccumulator::new(m, 0);
    let clock = Stopwatch::new();
    for _ in 0..reps {
        for (cx, cu) in &frames {
            let dx = cx.dequantized()?;
            let du = cu.dequantized()?;
            acc.fold(&dx, &du);
        }
        let z = problem.consensus_from_sum(acc.sum(), n)?;
        sink += z[0];
    }
    let mat_round_us = clock.elapsed_secs() * 1e6 / reps as f64;

    // dense fire-time work (refresh-style fold2 over all m coordinates +
    // the prox): serial blocked kernel vs the coordinate-sharded variant
    let a = rng.normal_vec(m, 0.0, 1.0);
    let b = rng.normal_vec(m, 0.0, 0.1);
    let mut kv = KahanVec::zeros(m);
    let clock = Stopwatch::new();
    for _ in 0..reps {
        kv.fold2(&a, &b);
        let z = problem.consensus_from_sum(kv.value(), n)?;
        sink += z[0];
    }
    let serial_fire_us = clock.elapsed_secs() * 1e6 / reps as f64;

    let shards = auto_shards(m);
    let mut kv = KahanVec::zeros(m);
    let clock = Stopwatch::new();
    for _ in 0..reps {
        kv.fold2_sharded(&a, &b, shards);
        let z = problem.consensus_from_sum(kv.value(), n)?;
        sink += z[0];
    }
    let sharded_fire_us = clock.elapsed_secs() * 1e6 / reps as f64;
    std::hint::black_box(sink);

    let speedup_fused = mat_round_us / fused_round_us.max(1e-9);
    let speedup_sharded = serial_fire_us / sharded_fire_us.max(1e-9);
    println!(
        "server_round_nn         n={n:5} m={m:7} P={p:4} k={k:4} shards={shards:2}  \
         fused {fused_round_us:9.1}us  materialized {mat_round_us:9.1}us ({speedup_fused:5.1}x)  \
         fire serial {serial_fire_us:9.1}us  sharded {sharded_fire_us:9.1}us ({speedup_sharded:4.1}x)"
    );
    Ok(Json::obj(vec![
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("p", Json::Num(p as f64)),
        ("k", Json::Num(k as f64)),
        ("reps", Json::Num(reps as f64)),
        ("shards", Json::Num(shards as f64)),
        ("fused_round_us", Json::Num(fused_round_us)),
        ("mat_round_us", Json::Num(mat_round_us)),
        ("speedup_fused", Json::Num(speedup_fused)),
        ("serial_fire_us", Json::Num(serial_fire_us)),
        ("sharded_fire_us", Json::Num(sharded_fire_us)),
        ("speedup_sharded", Json::Num(speedup_sharded)),
    ]))
}

// ---- deploy_loadgen: reactor socket server under a worker fleet ------------

/// One `serve --loadgen N` cell: N in-process workers over a UDS against
/// the sharded reactor, real frames on a real socket. Records rounds/s
/// (the throughput the O(shards)-thread server sustains), the shard count
/// (the thread bill: total server threads = io_threads + 1 regardless of
/// N), and p50/p99 round latency off the captured timeline. The run also
/// re-asserts the exact byte reconciliation under load — a loadgen cell
/// that drifted the books fails the bench, not just the tests.
fn deploy_loadgen_cell(nodes: usize, iters: usize) -> anyhow::Result<Json> {
    let r = qadmm::exp::deploy::run_loadgen(nodes, iters)?;
    println!(
        "deploy_loadgen          n={nodes:5} rounds={:4}  wall {:7.2}s  \
         rounds/s {:8.1}  io-threads {:2}  p50 {:>9}  p99 {:>9}",
        r.rounds,
        r.wall_s,
        r.rounds_per_s,
        r.io_threads,
        r.p50_s.map_or("n/a".into(), |p| format!("{:.0}us", p * 1e6)),
        r.p99_s.map_or("n/a".into(), |p| format!("{:.0}us", p * 1e6)),
    );
    Ok(Json::obj(vec![
        ("nodes", Json::Num(nodes as f64)),
        ("rounds", Json::Num(r.rounds as f64)),
        ("wall_s", Json::Num(r.wall_s)),
        ("rounds_per_s", Json::Num(r.rounds_per_s)),
        ("io_threads", Json::Num(r.io_threads as f64)),
        ("p50_us", r.p50_s.map_or(Json::Null, |p| Json::Num(p * 1e6))),
        ("p99_us", r.p99_s.map_or(Json::Null, |p| Json::Num(p * 1e6))),
        ("bytes_up", Json::Num(r.bytes_up as f64)),
        ("bytes_down", Json::Num(r.bytes_down as f64)),
    ]))
}

// ---- trigger: event-trigger dead-band / adaptive levels at scale -----------

/// One (n, δ, adapt) cell of the event-trigger section: the same straggler
/// timeline as the scale sweep, QSGD(4) uplinks, with the dead-band and
/// the adaptive level schedule toggled. Reports wall time (the gate is on
/// the dispatch hot path — this is the overhead guard), realized skip
/// fraction, and total accounted uplink bits (the savings the trigger
/// exists for; the δ=0 fixed row is the baseline).
fn trigger_cell(n: usize, rounds: usize, delta: f64, adapt: bool) -> anyhow::Result<Json> {
    let (m, h) = (1024usize, 8usize);
    let sweep = Sweep { n, m, h, rounds, tau: 4, link: straggler_link(), label: "trigger" };
    let mut cfg = base_cfg(&sweep);
    cfg.name = format!("engine-trigger-n{n}-d{delta:.0e}-{}", if adapt { "adapt" } else { "fixed" });
    cfg.compressor = CompressorKind::Qsgd { bits: 4 };
    cfg.trigger.delta = delta;
    cfg.trigger.adapt = adapt;
    let mut rngs = TrialRngs::new(cfg.seed);
    let mut problem = LassoProblem::generate(
        LassoConfig { m, h, n, rho: 50.0, theta: 0.1 },
        &mut rngs.data,
    )?;
    problem.set_reference_optimum(1.0);

    let clock = Stopwatch::new();
    let mut engine = EventEngine::new(&cfg, &mut problem, rngs)?;
    for _ in 0..rounds {
        engine.step_round()?;
    }
    let wall = clock.elapsed_secs();
    let stats = engine.stats();
    let skipped = engine.trigger().skipped();
    let uplink_bits = engine.accounting().total_uplink_bits();
    let skip_frac = skipped as f64 / (stats.dispatches.max(1)) as f64;
    println!(
        "trigger                 n={n:5} delta={delta:8.0e} levels={:8}  wall {wall:7.2}s  \
         dispatches {:>8}  skipped {:>8} ({:5.1}%)  uplink bits {}",
        if adapt { "adaptive" } else { "fixed" },
        stats.dispatches,
        skipped,
        100.0 * skip_frac,
        fmt_count(uplink_bits as f64),
    );
    Ok(Json::obj(vec![
        ("n", Json::Num(n as f64)),
        ("delta", Json::Num(delta)),
        ("adapt", Json::Bool(adapt)),
        ("rounds", Json::Num(rounds as f64)),
        ("wall_s", Json::Num(wall)),
        ("dispatches", Json::Num(stats.dispatches as f64)),
        ("skipped", Json::Num(skipped as f64)),
        ("skip_frac", Json::Num(skip_frac)),
        ("uplink_bits", Json::Num(uplink_bits as f64)),
    ]))
}

fn main() {
    let fast = std::env::var("QADMM_BENCH_FAST").is_ok();
    let mut sweeps = if fast {
        vec![
            scale_sweep(16, 200, 100, 50),
            scale_sweep(128, 512, 16, 20),
            scale_sweep(1024, 10_240, 4, 10),
        ]
    } else {
        vec![
            scale_sweep(16, 200, 100, 200),
            scale_sweep(128, 2048, 16, 200),
            scale_sweep(1024, 10_240, 4, 200),
        ]
    };

    // τ × downlink grid at n ∈ {256, 1024} (fast mode keeps n = 256 only):
    // delayed ẑ delivery is the per-link decomposition's hot path.
    let downlinks: [(LatencyModel, &'static str); 2] = [
        (LatencyModel::Const(0.05), "tauxdown-const"),
        (LatencyModel::Exp(0.25), "tauxdown-exp"),
    ];
    let grid_sizes: &[usize] = if fast { &[256] } else { &[256, 1024] };
    let grid_rounds = if fast { 10 } else { 100 };
    for &n in grid_sizes {
        for tau in [2usize, 8] {
            for (down, label) in downlinks {
                sweeps.push(Sweep {
                    n,
                    m: 1024,
                    h: 8,
                    rounds: grid_rounds,
                    tau,
                    link: LinkConfig {
                        compute: LatencyModel::Exp(0.01),
                        uplink: LatencyModel::Exp(0.01),
                        downlink: down,
                        clock_drift: 0.05,
                    },
                    label,
                });
            }
        }
    }

    println!("--- engine_scale: event-driven virtual-time QADMM ---");
    let mut sweep_records = Vec::new();
    for s in &sweeps {
        match run_sweep(s) {
            Ok(rec) => sweep_records.push(rec),
            Err(e) => {
                eprintln!("n={} ({}): {e:#}", s.n, s.label);
                std::process::exit(1);
            }
        }
    }

    // server fire cost: old full-recompute path vs incremental accumulator
    println!("--- server_round: O(n·m) bank sweep vs O(|A|·m) incremental ---");
    let (m, cells_n, reps): (usize, &[usize], usize) = if fast {
        (256, &[256, 1024], 20)
    } else {
        (1024, &[256, 1024, 4096], 30)
    };
    let mut server_records = Vec::new();
    for &n in cells_n {
        for p in [n / 8, n / 2, n] {
            match server_round_cell(n, m, p.max(1), reps) {
                Ok(rec) => server_records.push(rec),
                Err(e) => {
                    eprintln!("server_round n={n} p={p}: {e:#}");
                    std::process::exit(1);
                }
            }
        }
    }

    // NN-scale fused/sharded hot path: m up to 10^6 with top-k frames
    println!("--- server_round_nn: fused O(k) folds + sharded fires at NN-scale m ---");
    let (nn_ms, nn_p, nn_k, nn_reps): (&[usize], usize, usize, usize) = if fast {
        (&[100_000], 4, 256, 10)
    } else {
        (&[100_000, 1_000_000], 64, 256, 20)
    };
    let mut server_nn_records = Vec::new();
    for &m in nn_ms {
        match server_round_nn_cell(1024, m, nn_p, nn_k, nn_reps) {
            Ok(rec) => server_nn_records.push(rec),
            Err(e) => {
                eprintln!("server_round_nn m={m}: {e:#}");
                std::process::exit(1);
            }
        }
    }

    // event-trigger cells: δ=0 fixed is the baseline row; the gated and
    // adaptive rows show the uplink-bit savings and the hot-path overhead
    println!("--- trigger: dead-band delta x level schedule (qsgd4) ---");
    let trig_sizes: &[usize] = if fast { &[256] } else { &[256, 1024] };
    let trig_rounds = if fast { 10 } else { 100 };
    let mut trigger_records = Vec::new();
    for &n in trig_sizes {
        for (delta, adapt) in [(0.0, false), (1e-4, false), (1e-4, true)] {
            match trigger_cell(n, trig_rounds, delta, adapt) {
                Ok(rec) => trigger_records.push(rec),
                Err(e) => {
                    eprintln!("trigger n={n} delta={delta} adapt={adapt}: {e:#}");
                    std::process::exit(1);
                }
            }
        }
    }

    // reactor loadgen: hundreds of real socket workers against the
    // O(shards)-thread server — rounds/s is higher-is-better here
    println!("--- deploy_loadgen: reactor serve under N uds workers ---");
    let lg_cells: &[(usize, usize)] =
        if fast { &[(64, 30)] } else { &[(64, 60), (256, 40), (512, 30)] };
    let mut loadgen_records = Vec::new();
    for &(nodes, iters) in lg_cells {
        match deploy_loadgen_cell(nodes, iters) {
            Ok(rec) => loadgen_records.push(rec),
            Err(e) => {
                eprintln!("deploy_loadgen n={nodes}: {e:#}");
                std::process::exit(1);
            }
        }
    }

    // million-node cells: the O(active) memory acceptance bar. Fast mode
    // keeps the n = 10^5 smoke (seconds); the full run adds n = 10^6.
    println!("--- scale_xl: 10^5..10^6-node fleets, flat memory ---");
    let xl_cells: &[(usize, usize)] = if fast { &[(100_000, 3)] } else { &[(100_000, 5), (1_000_000, 3)] };
    let mut xl_records = Vec::new();
    for &(n, rounds) in xl_cells {
        match scale_xl_cell(n, rounds) {
            Ok(rec) => xl_records.push(rec),
            Err(e) => {
                eprintln!("scale_xl n={n}: {e:#}");
                std::process::exit(1);
            }
        }
    }

    // machine-readable trajectory record at the repo root. The provenance
    // field marks which machine class produced the numbers: rows before it
    // existed were authored on heterogeneous dev containers and are
    // order-of-magnitude estimates, not anchors — the first CI run on a
    // hosted runner becomes the comparable baseline the perf trajectory is
    // diffed against from then on. QADMM_BENCH_PROVENANCE overrides (e.g.
    // a dedicated perf box).
    let provenance = std::env::var("QADMM_BENCH_PROVENANCE").unwrap_or_else(|_| {
        if std::env::var("GITHUB_ACTIONS").is_ok() {
            "github-hosted-runner: first comparable anchor class for this file".into()
        } else {
            "local-dev-container: environment-dependent estimate, not an anchor".into()
        }
    });
    let out = Json::obj(vec![
        ("bench", Json::Str("engine_scale".into())),
        ("fast", Json::Bool(fast)),
        ("provenance", Json::Str(provenance)),
        ("sweeps", Json::Arr(sweep_records)),
        ("scale_xl", Json::Arr(xl_records)),
        ("server_round", Json::Arr(server_records)),
        ("server_round_nn", Json::Arr(server_nn_records)),
        ("deploy_loadgen", Json::Arr(loadgen_records)),
        ("trigger", Json::Arr(trigger_records)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");
    match std::fs::write(path, out.to_string_pretty()) {
        Ok(()) => println!("--- wrote {path} ---"),
        Err(e) => eprintln!("!! could not write {path}: {e}"),
    }
    println!("--- engine_scale: {} sweeps done ---", sweeps.len());
}
