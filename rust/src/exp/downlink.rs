//! Fig. 3-style τ × downlink-delay sweep at event-engine scale.
//!
//! The paper's τ sweep (Fig. 3) varies the staleness bound under the
//! selection oracle alone; here the other half of the asynchrony model is
//! turned on as well: the server's ẑ broadcast rides a per-node downlink
//! (odd-indexed nodes 4× slower, per [`crate::comm::profile`]), so nodes
//! compute against *delayed* mirrors of the consensus. The grid crosses
//! τ ∈ {2, 4, 8} with downlink ∈ {none, const, exp} at n ∈ {256, 1024} —
//! sizes only the virtual-time engine can sweep (a threaded run would
//! sleep through every injected delay).
//!
//! Invoke with `qadmm downlink [--iters N] [--trials N] [--quick]`.

use crate::admm::runner::{self, ProblemFactory};
use crate::comm::latency::LatencyModel;
use crate::comm::profile::LinkConfig;
use crate::compress::CompressorKind;
use crate::config::{presets, EngineKind, ExperimentConfig, OracleConfig, ProblemKind};
use crate::metrics::summary;
use crate::problems::lasso::{LassoConfig, LassoProblem};
use crate::problems::Problem;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct DownlinkRow {
    pub label: String,
    pub n: usize,
    pub tau: usize,
    pub downlink: String,
    pub final_accuracy: f64,
    pub bits_to_target: Option<f64>,
    pub total_bits: f64,
}

impl DownlinkRow {
    pub fn render(&self) -> String {
        format!(
            "{:36} final_acc {:>10.3e}  bits@target {:>12}  total_bits/param {:>12.1}",
            self.label,
            self.final_accuracy,
            self.bits_to_target
                .map(|b| format!("{b:.1}"))
                .unwrap_or_else(|| "n/a".into()),
            self.total_bits
        )
    }
}

pub struct DownlinkSweepOptions {
    pub iters: usize,
    pub mc_trials: usize,
    pub target: f64,
    /// Restrict to n = 256 (CI / smoke); the full grid adds n = 1024.
    pub quick: bool,
}

impl Default for DownlinkSweepOptions {
    fn default() -> Self {
        Self { iters: 120, mc_trials: 2, target: 1e-6, quick: false }
    }
}

/// The base mean delay every leg is scaled from (virtual seconds).
const BASE_DELAY: f64 = 0.01;

fn grid_points() -> Vec<(LatencyModel, &'static str)> {
    vec![
        (LatencyModel::None, "none"),
        (LatencyModel::Const(5.0 * BASE_DELAY), "const"),
        (LatencyModel::Exp(25.0 * BASE_DELAY), "exp"),
    ]
}

fn sweep_cfg(
    n: usize,
    tau: usize,
    downlink: LatencyModel,
    opts: &DownlinkSweepOptions,
) -> ExperimentConfig {
    let mut cfg = presets::ci_lasso();
    // Fig. 3 parameters scaled out to engine-size populations: the
    // Woodbury solver keeps h ≪ m cheap at n = 1024.
    cfg.problem = ProblemKind::Lasso { m: 256, h: 8, n, rho: 500.0, theta: 0.1 };
    cfg.compressor = CompressorKind::Qsgd { bits: 3 };
    cfg.engine = EngineKind::Event;
    cfg.tau = tau;
    cfg.p_min = (n / 4).max(1);
    cfg.iters = opts.iters;
    cfg.mc_trials = opts.mc_trials;
    cfg.eval_every = 1;
    cfg.oracle = OracleConfig { p_slow: 0.1, p_fast: 0.8, regroup_each_call: false };
    cfg.link = LinkConfig {
        compute: LatencyModel::Exp(BASE_DELAY),
        uplink: LatencyModel::Exp(BASE_DELAY),
        downlink,
        clock_drift: 0.05,
    };
    cfg
}

fn run_one(cfg: &ExperimentConfig, opts: &DownlinkSweepOptions) -> anyhow::Result<McRow> {
    let lcfg = match cfg.problem {
        ProblemKind::Lasso { m, h, n, rho, theta } => LassoConfig { m, h, n, rho, theta },
        _ => unreachable!(),
    };
    let mut factory: Box<ProblemFactory> = Box::new(move |_seed, data_rng: &mut Pcg64| {
        let mut p = LassoProblem::generate(lcfg, data_rng)?;
        if lcfg.n >= 1024 {
            // F* via thousands of FISTA rounds is the dominant cost at this
            // size; the sweep compares *relative* trajectories, so a fixed
            // reference keeps the accuracy metric monotone-comparable.
            p.set_reference_optimum(1.0);
        }
        Ok(Box::new(p) as Box<dyn Problem>)
    });
    let res = runner::run_mc(cfg, factory.as_mut())?;
    drop(factory);
    let rec = res.mean_recorder();
    Ok(McRow {
        final_accuracy: *res.mean_accuracy.last().unwrap(),
        bits_to_target: summary::bits_to_accuracy(&rec.records, opts.target),
        total_bits: *res.mean_comm_bits.last().unwrap(),
    })
}

struct McRow {
    final_accuracy: f64,
    bits_to_target: Option<f64>,
    total_bits: f64,
}

/// Run the τ × downlink grid, printing one table per node count.
pub fn run(opts: &DownlinkSweepOptions) -> anyhow::Result<Vec<DownlinkRow>> {
    let sizes: &[usize] = if opts.quick { &[256] } else { &[256, 1024] };
    let mut all = Vec::new();
    for &n in sizes {
        println!("--- downlink sweep: n = {n} (tau x downlink-delay) ---");
        for tau in [2usize, 4, 8] {
            for (downlink, dlabel) in grid_points() {
                let cfg = sweep_cfg(n, tau, downlink, opts);
                let r = run_one(&cfg, opts)?;
                let row = DownlinkRow {
                    label: format!("n={n} tau={tau} downlink={dlabel}"),
                    n,
                    tau,
                    downlink: dlabel.into(),
                    final_accuracy: r.final_accuracy,
                    bits_to_target: r.bits_to_target,
                    total_bits: r.total_bits,
                };
                println!("{}", row.render());
                all.push(row);
            }
        }
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One tiny grid point end-to-end: the sweep config validates and a
    /// delayed-downlink event run completes with a sane accuracy series.
    #[test]
    fn one_grid_point_runs() {
        let opts =
            DownlinkSweepOptions { iters: 8, mc_trials: 1, target: 1e-6, quick: true };
        let mut cfg = sweep_cfg(8, 3, LatencyModel::Const(0.05), &opts);
        cfg.problem = ProblemKind::Lasso { m: 16, h: 6, n: 8, rho: 50.0, theta: 0.1 };
        cfg.validate().unwrap();
        let r = run_one(&cfg, &opts).unwrap();
        assert!(r.final_accuracy.is_finite());
        assert!(r.total_bits > 0.0);
    }
}
