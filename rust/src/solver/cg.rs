//! Conjugate gradient for SPD systems (alternative exact-solve path and a
//! cross-check for the Cholesky route).

use super::linalg::{axpy, dot, norm2, Mat};

/// Solve `A x = b` for SPD `A` to relative residual `tol`, at most
/// `max_iters` iterations. Returns (x, iterations, final relative residual).
pub fn solve_spd(a: &Mat, b: &[f64], tol: f64, max_iters: usize) -> (Vec<f64>, usize, f64) {
    let n = b.len();
    assert_eq!(a.rows, n);
    let bnorm = norm2(b).max(f64::MIN_POSITIVE);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut rs = dot(&r, &r);
    let mut iters = 0;
    for k in 0..max_iters {
        iters = k + 1;
        let ap = a.matvec(&p);
        let alpha = rs / dot(&p, &ap).max(f64::MIN_POSITIVE);
        axpy(&mut x, alpha, &p);
        axpy(&mut r, -alpha, &ap);
        let rs_new = dot(&r, &r);
        if rs_new.sqrt() / bnorm < tol {
            return (x, iters, rs_new.sqrt() / bnorm);
        }
        let beta = rs_new / rs;
        for (pi, ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs = rs_new;
    }
    (x, iters, rs.sqrt() / bnorm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn solves_well_conditioned_system() {
        let mut rng = Pcg64::seed_from_u64(1);
        let a = Mat { rows: 30, cols: 20, data: rng.normal_vec(600, 0.0, 1.0) };
        let mut spd = a.gram();
        spd.add_diag_in_place(5.0);
        let x_true = rng.normal_vec(20, 0.0, 1.0);
        let b = spd.matvec(&x_true);
        let (x, iters, res) = solve_spd(&spd, &b, 1e-12, 200);
        assert!(res < 1e-10, "res={res} iters={iters}");
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn agrees_with_cholesky() {
        let mut rng = Pcg64::seed_from_u64(2);
        let a = Mat { rows: 25, cols: 15, data: rng.normal_vec(375, 0.0, 1.0) };
        let mut spd = a.gram();
        spd.add_diag_in_place(3.0);
        let b = rng.normal_vec(15, 0.0, 1.0);
        let l = spd.cholesky().unwrap();
        let x_chol = Mat::cholesky_solve(&l, &b);
        let (x_cg, _, _) = solve_spd(&spd, &b, 1e-13, 300);
        for (a, b) in x_cg.iter().zip(&x_chol) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let spd = Mat::eye(5);
        let (x, _, _) = solve_spd(&spd, &[0.0; 5], 1e-12, 10);
        assert!(x.iter().all(|&v| v == 0.0));
    }
}
