"""L1 Pallas kernel: the paper's stochastic multi-level quantizer C(Δ) (eq. 17).

Given Δ ∈ R^M and S quantization levels (S = 2^(q-1) - 1 for q bits/scalar),
each element is normalized by ‖Δ‖_max, stochastically rounded to one of the
S+1 lattice points {0, 1/S, ..., 1} (unbiased: P[round up] equals the
fractional position inside the interval), and the sign/magnitude restored:

    [C(Δ)]_m = ‖Δ‖_max · sgn(Δ_m) · h(Δ_m, S)

The Bernoulli draws are *inputs* (a uniform[0,1) tensor supplied by the rust
coordinator's seeded PCG64), so the lowered HLO is a pure function and Monte
Carlo trials are exactly reproducible.

The kernel emits both the dequantized values (used for the error-feedback
update of the estimates x̂/û/ẑ) and the signed integer levels in [-S, S]
(what the rust wire layer bit-packs to q bits/scalar).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the max-norm reduction is
done by the surrounding jnp (XLA reduce); the kernel body is a fused
elementwise block over BLOCK-sized tiles — pure VPU work with a BlockSpec
expressing the HBM→VMEM tiling. interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size: one lane-aligned VMEM block per grid step. 256 elements keeps
# the (delta, noise, values, levels) working set tiny; on a real TPU this
# would be sized to a multiple of the (8, 128) vreg tile.
BLOCK = 256


def _quantize_kernel(delta_ref, noise_ref, norm_ref, s_ref, val_ref, lvl_ref):
    """One BLOCK tile of eq. (17). All refs are VMEM blocks."""
    d = delta_ref[...]
    noise = noise_ref[...]
    norm = norm_ref[0]
    s = s_ref[0]

    nonzero = norm > 0
    safe_norm = jnp.where(nonzero, norm, jnp.ones_like(norm))
    # Normalized magnitude in [0, S].
    y = jnp.abs(d) / safe_norm * s
    # Interval index p ∈ {0, ..., S-1}; y == S (the max element) lands in the
    # top interval with frac == 1, i.e. it always rounds up and is exact.
    p = jnp.minimum(jnp.floor(y), s - 1.0)
    frac = y - p
    up = (noise < frac).astype(d.dtype)
    lvl = p + up
    sgn = jnp.sign(d)
    val = jnp.where(nonzero, norm * sgn * lvl / s, jnp.zeros_like(d))
    val_ref[...] = val
    lvl_ref[...] = jnp.where(
        nonzero, sgn * lvl, jnp.zeros_like(lvl)
    ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def quantize(delta, noise, s, *, block=BLOCK):
    """C(Δ) with stochastic rounding driven by `noise` ~ U[0,1)^M.

    Args:
      delta: [M] f32/f64, the tensor to compress.
      noise: [M] same dtype, uniform draws (one per element).
      s: scalar, number of quantization intervals S (float-valued).
      block: tile size for the Pallas grid.

    Returns:
      (values [M], levels int32 [M] in [-S, S], norm scalar ‖Δ‖_max).
    """
    if delta.ndim != 1:
        raise ValueError(f"quantize expects rank-1 input, got {delta.shape}")
    m = delta.shape[0]
    dtype = delta.dtype
    norm = jnp.max(jnp.abs(delta)).reshape((1,))
    s_arr = jnp.asarray(s, dtype=dtype).reshape((1,))

    pad = (-m) % block
    if pad:
        delta_p = jnp.pad(delta, (0, pad))
        noise_p = jnp.pad(noise, (0, pad), constant_values=1.0)
    else:
        delta_p, noise_p = delta, noise
    mp = m + pad
    grid = (mp // block,)

    val, lvl = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), dtype),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
        ],
        interpret=True,
    )(delta_p, noise_p, norm, s_arr)
    if pad:
        val, lvl = val[:m], lvl[:m]
    return val, lvl, norm[0]
