//! In-tree substrates: seeded RNG, JSON, CLI parsing, statistics, timing.
//!
//! The offline build environment only vendors the `xla` crate closure, so
//! these stand in for `rand`, `serde_json`, `clap` and friends (DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod log;
pub mod mem;
pub mod rng;
pub mod stats;
pub mod timer;
