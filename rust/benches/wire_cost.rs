//! §4's motivating communication-cost table, regenerated with *measured*
//! wire bytes: per-iteration uplink cost of one node for M = 10⁷ parameters
//! (the paper's "640 MB per iteration" example) across precisions, plus the
//! exact bytes of every compressor at practical sizes.

use qadmm::bench_harness::Bencher;
use qadmm::compress::{Compressor, CompressorKind};
use qadmm::util::rng::Pcg64;
use qadmm::util::timer::fmt_count;

fn main() {
    let mut rng = Pcg64::seed_from_u64(3);

    println!("--- §4 motivating table: one node's uplink per iteration (x and u) ---");
    println!("{:>12} {:>14} {:>14} {:>12}", "scheme", "bits/scalar", "M=1e7 bytes", "vs fp64");
    // measure on a 1e5 slice and scale exactly (frames are linear in M
    // apart from the constant header)
    let m_probe = 100_000usize;
    let m_target = 10_000_000f64;
    let delta = rng.normal_vec(m_probe, 0.0, 1.0);
    let schemes: Vec<(String, CompressorKind)> = vec![
        ("fp64".into(), CompressorKind::Identity),
        ("qsgd8".into(), CompressorKind::Qsgd { bits: 8 }),
        ("qsgd4".into(), CompressorKind::Qsgd { bits: 4 }),
        ("qsgd3".into(), CompressorKind::Qsgd { bits: 3 }),
        ("sign".into(), CompressorKind::Sign),
        ("topk1%".into(), CompressorKind::TopK { frac_permille: 10 }),
    ];
    let mut fp64_bytes = 0f64;
    for (name, kind) in &schemes {
        let c = kind.build();
        let wire = c.compress(&delta, &mut rng).wire;
        let bits_per_scalar = wire.len() as f64 * 8.0 / m_probe as f64;
        // the paper counts both x and u on the uplink: 2 vectors
        let bytes_1e7 = 2.0 * bits_per_scalar * m_target / 8.0;
        if name == "fp64" {
            fp64_bytes = bytes_1e7;
        }
        println!(
            "{name:>12} {bits_per_scalar:>14.3} {:>13}B {:>11.1}%",
            fmt_count(bytes_1e7),
            100.0 * bytes_1e7 / fp64_bytes
        );
    }

    // end-to-end wire timing: how long does encoding 2×M scalars take
    let mut b = Bencher::new();
    for kind in [CompressorKind::Qsgd { bits: 3 }, CompressorKind::Identity] {
        let c = kind.build();
        b.bench_val(&format!("{}/encode_uplink/m={m_probe}", kind.label()), m_probe, || {
            (c.compress(&delta, &mut rng), c.compress(&delta, &mut rng))
        });
    }
    b.finish("wire_cost");
}
