//! Headline summaries: "QADMM requires X% fewer communication bits than the
//! unquantized version to reach accuracy Y" (the paper's 90.62% / 91.02%).

use super::IterRecord;

/// First cumulative comm-bits value at which `reached` becomes true and
/// stays measurable (first crossing). Returns None if never reached.
pub fn bits_to_reach(records: &[IterRecord], reached: impl Fn(&IterRecord) -> bool) -> Option<f64> {
    records.iter().find(|r| reached(r)).map(|r| r.comm_bits)
}

/// Bits until eq.-19 accuracy drops to `target` (LASSO-style, lower=better).
pub fn bits_to_accuracy(records: &[IterRecord], target: f64) -> Option<f64> {
    bits_to_reach(records, |r| r.accuracy.is_finite() && r.accuracy <= target)
}

/// Bits until test accuracy rises to `target` (classification, higher=better).
pub fn bits_to_test_acc(records: &[IterRecord], target: f64) -> Option<f64> {
    bits_to_reach(records, |r| r.test_acc.is_finite() && r.test_acc >= target)
}

/// Percentage reduction of `ours` relative to `baseline` (positive = fewer).
pub fn reduction_pct(ours: f64, baseline: f64) -> f64 {
    100.0 * (1.0 - ours / baseline)
}

/// Pretty summary row used by the figure drivers.
pub fn headline_row(
    label: &str,
    target_desc: &str,
    ours: Option<f64>,
    baseline: Option<f64>,
) -> String {
    match (ours, baseline) {
        (Some(o), Some(b)) => format!(
            "{label}: to reach {target_desc}: QADMM {o:.1} bits/param vs baseline {b:.1} \
             bits/param  =>  {:.2}% reduction",
            reduction_pct(o, b)
        ),
        (o, b) => format!(
            "{label}: to reach {target_desc}: QADMM {:?} vs baseline {:?} (not reached)",
            o, b
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, acc: f64, test_acc: f64, bits: f64) -> IterRecord {
        IterRecord {
            iter,
            comm_bits: bits,
            accuracy: acc,
            test_acc,
            loss: 0.0,
            active_nodes: 1,
            wall_s: 0.0,
        }
    }

    #[test]
    fn first_crossing_downward() {
        let recs = vec![
            rec(0, 1.0, 0.2, 10.0),
            rec(1, 1e-3, 0.5, 20.0),
            rec(2, 1e-11, 0.9, 30.0),
            rec(3, 1e-12, 0.96, 40.0),
        ];
        assert_eq!(bits_to_accuracy(&recs, 1e-10), Some(30.0));
        assert_eq!(bits_to_test_acc(&recs, 0.95), Some(40.0));
        assert_eq!(bits_to_accuracy(&recs, 1e-20), None);
    }

    #[test]
    fn reduction_matches_paper_arithmetic() {
        // 90.62% reduction means ours = 9.38% of baseline
        let r = reduction_pct(9.38, 100.0);
        assert!((r - 90.62).abs() < 1e-9);
    }

    #[test]
    fn nan_records_are_skipped() {
        let recs = vec![rec(0, f64::NAN, f64::NAN, 5.0), rec(1, 0.5, 0.99, 10.0)];
        assert_eq!(bits_to_accuracy(&recs, 0.6), Some(10.0));
        assert_eq!(bits_to_test_acc(&recs, 0.9), Some(10.0));
    }

    #[test]
    fn headline_row_formats() {
        let s = headline_row("LASSO", "1e-10", Some(10.0), Some(100.0));
        assert!(s.contains("90.00% reduction"));
        let s2 = headline_row("LASSO", "1e-10", None, Some(1.0));
        assert!(s2.contains("not reached"));
    }
}
