//! Per-link latency decomposition (the paper's Fig. 2 asynchrony model).
//!
//! A node's round trip is staled by *three* independent delay sources, not
//! one: the local compute time, the uplink transit of its compressed
//! update, and the downlink transit of the server's ẑ broadcast. The seed
//! engines collapsed all of these into a single per-node [`LatencyModel`]
//! (and delivered the broadcast instantaneously), which understates the
//! staleness the τ bound has to absorb. This module splits the link into
//! its legs:
//!
//! * [`LinkConfig`] — the population-level specification carried by
//!   [`crate::config::ExperimentConfig`]: one base model per leg plus a
//!   clock-drift amplitude.
//! * [`LinkProfile`] — one node's realized link after heterogeneity is
//!   applied (odd-indexed nodes are 4× slower per leg, mirroring
//!   [`per_node_latencies`]) with the node's resolved clock-rate factor.
//!
//! Clock drift models unsynchronized node clocks: node i's local compute
//! clock runs at rate `1 + ε·spread(i)` with `spread` deterministically
//! spaced over [−1, 1], so nominal compute duration D takes `D / rate`
//! server-seconds — a slow-clocked node (rate < 1) stretches its compute
//! time, a fast one shrinks it. Drift scales *compute* only — wire
//! transit is measured on the server's clock. With every leg `None` the
//! profile is exactly the zero-latency parity configuration: drift
//! divides a 0.0 sample and the engine timeline collapses onto the
//! sequential simulator.

use super::latency::{per_node_latencies, LatencyModel};
use crate::util::rng::Pcg64;

/// Population-level link specification (one per experiment).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkConfig {
    /// Local update duration.
    pub compute: LatencyModel,
    /// Node → server transit of the compressed (Δx, Δu) frame.
    pub uplink: LatencyModel,
    /// Server → node transit of the compressed Δz broadcast.
    pub downlink: LatencyModel,
    /// Maximum relative clock-rate skew ε ∈ [0, 1): node rates are spread
    /// deterministically over [1−ε, 1+ε]. 0.0 = perfectly synchronized.
    pub clock_drift: f64,
}

impl LinkConfig {
    /// Zero delay on every leg, no drift (the parity configuration).
    pub const fn none() -> Self {
        Self {
            compute: LatencyModel::None,
            uplink: LatencyModel::None,
            downlink: LatencyModel::None,
            clock_drift: 0.0,
        }
    }

    /// The seed engines' shape: one model drawn for compute and again for
    /// uplink, instantaneous downlink. Kept for sweeps that predate the
    /// decomposition.
    pub const fn symmetric(model: LatencyModel) -> Self {
        Self {
            compute: model,
            uplink: model,
            downlink: LatencyModel::None,
            clock_drift: 0.0,
        }
    }

    /// Delay on the uplink only (the seed threaded runtime's shape, where
    /// the injected sleep lived in `NodeEndpoint::send`).
    pub const fn uplink_only(model: LatencyModel) -> Self {
        Self {
            compute: LatencyModel::None,
            uplink: model,
            downlink: LatencyModel::None,
            clock_drift: 0.0,
        }
    }

    /// True iff no leg can ever delay anything (drift is then irrelevant:
    /// it multiplies 0.0 samples).
    pub fn is_zero(&self) -> bool {
        self.compute == LatencyModel::None
            && self.uplink == LatencyModel::None
            && self.downlink == LatencyModel::None
    }
}

impl Default for LinkConfig {
    fn default() -> Self {
        Self::none()
    }
}

/// One node's realized link: per-leg delay models plus the node's local
/// clock rate relative to the server's (1.0 = perfectly synchronized).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkProfile {
    pub compute: LatencyModel,
    pub uplink: LatencyModel,
    pub downlink: LatencyModel,
    pub clock_drift: f64,
}

impl LinkProfile {
    /// Zero delay on every leg at nominal clock rate.
    pub const fn none() -> Self {
        Self {
            compute: LatencyModel::None,
            uplink: LatencyModel::None,
            downlink: LatencyModel::None,
            clock_drift: 1.0,
        }
    }

    /// Local update duration *as seen by the server's clock*: work of
    /// nominal duration D on a clock running at rate r completes in D / r
    /// server-seconds, so a fast-clocked node (r > 1) finishes sooner.
    pub fn sample_compute(&self, rng: &mut Pcg64) -> f64 {
        self.compute.sample(rng) / self.clock_drift
    }

    pub fn sample_uplink(&self, rng: &mut Pcg64) -> f64 {
        self.uplink.sample(rng)
    }

    pub fn sample_downlink(&self, rng: &mut Pcg64) -> f64 {
        self.downlink.sample(rng)
    }

    /// Expected dispatch→arrival time (analytic estimates in benches).
    pub fn mean_round_trip(&self) -> f64 {
        self.compute.mean() / self.clock_drift + self.uplink.mean() + self.downlink.mean()
    }
}

/// Deterministic drift spread over [−1, 1] (node 0 slowest-clocked, node
/// n−1 fastest): heterogeneous but reproducible, like the odd-node
/// latency slowdown.
fn drift_spread(i: usize, n: usize) -> f64 {
    if n <= 1 {
        0.0
    } else {
        2.0 * (i as f64) / ((n - 1) as f64) - 1.0
    }
}

/// Realize per-node profiles from one population spec: each leg goes
/// through [`per_node_latencies`] (odd-indexed nodes 4× slower), and the
/// drift amplitude resolves to a per-node clock-rate factor.
///
/// Hierarchical fan-in ([`crate::topology`]) realizes its aggregator links
/// with a *separate* call (indexed over the aggregator count), so adding a
/// tier never perturbs the leaf population — leaf profiles depend only on
/// the leaf index and count. Aggregators use the uplink leg for their
/// re-quantized upstream forwards; their compute/downlink legs and drift
/// are inert (aggregation is O(m) folding, modeled as instantaneous).
pub fn per_node_profiles(cfg: LinkConfig, n: usize) -> Vec<LinkProfile> {
    let compute = per_node_latencies(cfg.compute, n);
    let uplink = per_node_latencies(cfg.uplink, n);
    let downlink = per_node_latencies(cfg.downlink, n);
    (0..n)
        .map(|i| LinkProfile {
            compute: compute[i],
            uplink: uplink[i],
            downlink: downlink[i],
            clock_drift: 1.0 + cfg.clock_drift * drift_spread(i, n),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_node_slows_odd_nodes_on_every_leg() {
        let cfg = LinkConfig {
            compute: LatencyModel::Const(0.1),
            uplink: LatencyModel::Const(0.2),
            downlink: LatencyModel::Const(0.3),
            clock_drift: 0.0,
        };
        let p = per_node_profiles(cfg, 4);
        assert_eq!(p[0].compute, LatencyModel::Const(0.1));
        assert_eq!(p[1].compute, LatencyModel::Const(0.4));
        assert_eq!(p[0].downlink, LatencyModel::Const(0.3));
        assert_eq!(p[1].downlink, LatencyModel::Const(1.2));
        assert!(p.iter().all(|q| q.clock_drift == 1.0));
    }

    #[test]
    fn drift_spreads_over_unit_interval() {
        let cfg = LinkConfig { clock_drift: 0.1, ..LinkConfig::none() };
        let p = per_node_profiles(cfg, 5);
        assert!((p[0].clock_drift - 0.9).abs() < 1e-12);
        assert!((p[2].clock_drift - 1.0).abs() < 1e-12);
        assert!((p[4].clock_drift - 1.1).abs() < 1e-12);
        // a single node gets the nominal rate
        assert_eq!(per_node_profiles(cfg, 1)[0].clock_drift, 1.0);
    }

    #[test]
    fn drift_scales_compute_only() {
        let mut rng = Pcg64::seed_from_u64(1);
        let p = LinkProfile {
            compute: LatencyModel::Const(2.0),
            uplink: LatencyModel::Const(2.0),
            downlink: LatencyModel::Const(2.0),
            clock_drift: 2.0,
        };
        // a clock at rate 2 finishes nominal 2.0s of work in 1.0s
        assert_eq!(p.sample_compute(&mut rng), 1.0);
        assert_eq!(p.sample_uplink(&mut rng), 2.0);
        assert_eq!(p.sample_downlink(&mut rng), 2.0);
        assert_eq!(p.mean_round_trip(), 5.0);
        // and a slow clock (rate 1/2) takes twice the nominal duration
        let slow = LinkProfile { clock_drift: 0.5, ..p };
        assert_eq!(slow.sample_compute(&mut rng), 4.0);
    }

    #[test]
    fn zero_config_stays_zero_under_drift() {
        let cfg = LinkConfig { clock_drift: 0.5, ..LinkConfig::none() };
        assert!(cfg.is_zero());
        let mut rng = Pcg64::seed_from_u64(2);
        for p in per_node_profiles(cfg, 8) {
            assert_eq!(p.sample_compute(&mut rng), 0.0);
            assert_eq!(p.sample_downlink(&mut rng), 0.0);
        }
    }

    #[test]
    fn legacy_shapes() {
        let s = LinkConfig::symmetric(LatencyModel::Exp(0.1));
        assert_eq!(s.compute, LatencyModel::Exp(0.1));
        assert_eq!(s.uplink, LatencyModel::Exp(0.1));
        assert_eq!(s.downlink, LatencyModel::None);
        let u = LinkConfig::uplink_only(LatencyModel::Const(0.2));
        assert_eq!(u.compute, LatencyModel::None);
        assert_eq!(u.uplink, LatencyModel::Const(0.2));
        assert!(!u.is_zero());
    }
}
