//! Server loop: arrival-driven Algorithm 1. Triggers a consensus round once
//! at least `P` nodes have reported *and* every node at staleness τ−1 is
//! among them (the bounded-delay rule); broadcasts the compressed consensus
//! delta; repeats for the configured number of rounds.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use crate::comm::message::{
    NodeToServer, ServerToNode, INIT_BITS_PER_SCALAR, MSG_HEADER_BYTES,
};
use crate::comm::network::{ServerEndpoint, SharedAccounting};
use crate::compress::error_feedback::{estimate_rows, EstimateTracker};
use crate::compress::{Compressed, Compressor};
use crate::config::ExperimentConfig;
use crate::metrics::{IterRecord, RunRecorder};
use crate::problems::accumulator::ConsensusAccumulator;
use crate::problems::Arena;
use crate::snapshot::timeline::RecordedTimeline;
use crate::topology::AggregatorTier;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::SharedProblem;

/// Everything one server run produces besides the side effects on the
/// shared accounting: the metrics stream, the replay-mode arrival audit,
/// and (when [`ServerLoop::set_record`] was called) the captured schedule.
pub struct ServerRunOutput {
    pub recorder: RunRecorder,
    /// Replay mode only: the realized arrival set of every fired round.
    pub round_arrivals: Vec<Vec<usize>>,
    /// The recorded production schedule (deploy capture→replay workflow).
    pub timeline: Option<RecordedTimeline>,
}

pub struct ServerLoop {
    ep: ServerEndpoint,
    problem: SharedProblem,
    accounting: SharedAccounting,
    compressor: Box<dyn Compressor>,
    m: usize,
    n: usize,
    tau: usize,
    p_min: usize,
    iters: usize,
    eval_every: usize,
    xhat: Vec<EstimateTracker>,
    uhat: Vec<EstimateTracker>,
    zhat: Option<EstimateTracker>,
    /// Incremental consensus sum: each arrival folds its wire frames in
    /// directly — no dequantized intermediate — in real arrival order (no
    /// bitwise replay claim in the deployment shape, only the accumulator's
    /// drift bound), so the per-round consensus is O(m) + the
    /// every-K-rounds refresh.
    acc: ConsensusAccumulator,
    /// Non-star fan-in, colocated with the server thread: arrivals
    /// route through their aggregator, which re-quantizes the partial sum
    /// and charges its own link (n + g). In the deployment shape there is
    /// no virtual timeline to batch against, so a ready aggregator flushes
    /// as soon as an arrival lands (P_g batching is an in-process-engine
    /// lever; liveness beats batching on real channels).
    tier: Option<AggregatorTier>,
    rng_topology: Pcg64,
    /// Event-trigger dead-band δ for the colocated aggregator tier: a
    /// ready partial with ‖pending‖∞ ≤ δ forwards credit only (zero bits).
    /// 0.0 disables the gate (every ready partial re-quantizes as before).
    trigger_delta: f64,
    d: Vec<usize>,
    pending: BTreeSet<usize>,
    /// Deploy churn: nodes currently attached. A [`NodeToServer::Leave`]
    /// (synthesized by the transport on EOF/error) clears the slot so the
    /// P/τ stale rule and the shutdown drain only ever wait on peers that
    /// can still answer; a mid-run `InitFull` from a dead slot is a rejoin
    /// (fresh bank state, fresh downlink basis). In-process runtimes never
    /// send `Leave`, so every slot stays live and nothing changes.
    live: Vec<bool>,
    /// Deploy capture ([`Self::set_record`]): the production schedule —
    /// wall-clock round times + arrival sets — in the PR 5 recording
    /// format, so a real deployment's cadence replays offline.
    record: Option<RecordedTimeline>,
    rng: Pcg64,
    /// Replay mode ([`Self::set_replay`]): the per-round arrival sets of a
    /// recorded event-engine timeline. Round r folds **exactly** these
    /// nodes' updates — anything else that lands early is held back in
    /// [`Self::stash`] until the round the recording assigns it to, so the
    /// deployment reproduces the engine's partial-participation schedule
    /// without any wall-clock sleeps.
    replay: Option<Vec<Vec<usize>>>,
    /// Updates that arrived ahead of their recorded round (replay mode
    /// only), held as wire frames. At most one per node: a node recomputes
    /// only after its previous update was folded into a broadcast it has
    /// seen.
    stash: BTreeMap<usize, (Compressed, Compressed)>,
    /// Dead-banded (zero-payload) reports that arrived ahead of their
    /// recorded round (replay mode only). Disjoint from [`Self::stash`]
    /// by the same one-in-flight cadence: a node's dispatch is either a
    /// payload or a skip, never both.
    skip_stash: BTreeSet<usize>,
    /// Replay mode only: the realized arrival set of every fired round
    /// (ascending) — what the replay-parity tests diff against the
    /// recording. Left empty in normal runs (a long deployment would
    /// otherwise accumulate one id vector per round for nobody).
    round_arrivals: Vec<Vec<usize>>,
    /// How long the server will wait for a required (stale) node before
    /// declaring the deployment wedged.
    pub stall_timeout: Duration,
}

impl ServerLoop {
    pub fn new(
        ep: ServerEndpoint,
        problem: SharedProblem,
        accounting: SharedAccounting,
        cfg: &ExperimentConfig,
        x0: Vec<f64>,
        m: usize,
        mut rng: Pcg64,
    ) -> Self {
        let n = ep.n_nodes();
        let ef = cfg.error_feedback;
        let rng_topology = rng.fork(0x746f_706f);
        Self {
            ep,
            problem,
            accounting,
            compressor: cfg.compressor.build(),
            m,
            n,
            tau: cfg.tau,
            p_min: cfg.p_min,
            iters: cfg.iters,
            eval_every: cfg.eval_every,
            xhat: (0..n).map(|_| EstimateTracker::new(x0.clone(), ef)).collect(),
            uhat: (0..n).map(|_| EstimateTracker::new(vec![0.0; m], ef)).collect(),
            zhat: None,
            acc: ConsensusAccumulator::new(m, cfg.consensus_refresh_every),
            tier: AggregatorTier::new(cfg.topology, n, m, cfg.p_tier, ef),
            rng_topology,
            trigger_delta: cfg.trigger.delta,
            d: vec![0; n],
            pending: BTreeSet::new(),
            live: vec![true; n],
            record: None,
            rng,
            replay: None,
            stash: BTreeMap::new(),
            skip_stash: BTreeSet::new(),
            round_arrivals: Vec::new(),
            stall_timeout: Duration::from_secs(60),
        }
    }

    /// Drive the round loop from a recorded timeline's arrival sets
    /// instead of real arrival order. The round count becomes the
    /// recording's (`cfg.iters` is ignored), and the fan-in must be the
    /// star — aggregator routing consumes RNG draws the recording never
    /// made (validated by [`super::run_threaded_replay`]).
    pub fn set_replay(&mut self, rounds: Vec<Vec<usize>>) {
        self.replay = Some(rounds);
    }

    /// Capture the run's schedule (round fire times + arrival sets) into a
    /// PR 5 [`RecordedTimeline`], so a production deployment's cadence can
    /// be replayed offline ([`crate::admm::replay`]). `engine` names the
    /// producer (the deploy server records as `"deploy"`).
    pub fn set_record(&mut self, engine: &str, seed: u64) {
        self.record = Some(RecordedTimeline::new(engine, self.n, seed));
    }

    pub fn run(mut self) -> anyhow::Result<ServerRunOutput> {
        let clock = Stopwatch::new();
        let mut recorder = RunRecorder::new();

        // ---- init: collect full-precision (x⁰, u⁰) from every node ----
        // (idempotent per node: the fault injector may duplicate InitFull)
        let mut inited = vec![false; self.n];
        while inited.iter().zip(&self.live).any(|(i, l)| *l && !i) {
            let msg = match self.ep.recv_timeout(self.stall_timeout)? {
                Some(m) => m,
                None => anyhow::bail!(
                    "init handshake stalled: inited {inited:?}, live {:?}",
                    self.live
                ),
            };
            match msg {
                NodeToServer::InitFull { node, x0, u0 } => {
                    anyhow::ensure!(
                        x0.len() == self.m && u0.len() == self.m,
                        "init frame dimension mismatch (expected {})",
                        self.m
                    );
                    self.xhat[node].reset(&x0);
                    self.uhat[node].reset(&u0);
                    inited[node] = true;
                    self.live[node] = true;
                }
                // a node that dies during the handshake is simply not
                // waited for; its banks keep the constructor state
                NodeToServer::Leave { node } => self.live[node] = false,
                NodeToServer::ShutdownAck { .. } => {}
                NodeToServer::Update { .. } | NodeToServer::Skip { .. } => {
                    anyhow::bail!("update before init handshake completed")
                }
            }
        }
        anyhow::ensure!(
            self.live.iter().any(|l| *l),
            "every node left before the init handshake completed"
        );
        // Non-star fan-in: seed the aggregator partials with the collected
        // init state and charge the aggregated full-precision forwards on
        // the aggregator links (n + g), mirroring the in-process engines.
        if let Some(t) = &mut self.tier {
            for leaf in 0..self.n {
                let parent = t.static_parent(leaf);
                t.seed_partial(parent, self.xhat[leaf].estimate(), self.uhat[leaf].estimate());
            }
            let mut acc = self.accounting.lock().unwrap();
            for g in 0..t.n_aggregators() {
                acc.record_uplink(
                    self.n + g,
                    MSG_HEADER_BYTES * 8 + 2 * self.m as u64 * INIT_BITS_PER_SCALAR,
                );
            }
        }
        // seed the incremental sum with one full bank sweep (from the ŝ_g
        // partials under a tier), then fold arrivals in as they land
        self.refresh_sum();
        let z = self.consensus()?;
        self.ep.broadcast(&ServerToNode::InitZ { z0: z.clone() })?;
        self.zhat = Some(EstimateTracker::new(z, true));

        // ---- main rounds ----
        // In replay mode the recording *is* the plan: exactly its rounds,
        // each folding exactly its recorded arrival set.
        let iters = self.replay.as_ref().map_or(self.iters, Vec::len);
        for r in 0..iters {
            if self.replay.is_some() {
                self.gather_replay(r)?;
                self.round_arrivals.push(self.pending.iter().copied().collect());
            } else {
                self.gather_batch()?;
            }
            if self.acc.refresh_due(r + 1) {
                self.refresh_sum();
            }
            let z = self.consensus()?;
            let dz = self.zhat.as_mut().unwrap().make_delta(&z);
            let cz = self.compressor.compress(&dz, &mut self.rng);
            // materialize the broadcast once (before the wire buffer moves
            // into the message), then commit it dense — same op order as
            // the in-process engines' shared downlink payload
            let dz_deq = cz.dequantized()?;
            // BTreeSet iteration is ascending, matching the wire contract.
            let included: Vec<u32> = self.pending.iter().map(|&i| i as u32).collect();
            let last = r + 1 == iters;
            if let Some(tl) = &mut self.record {
                let arrivals: Vec<usize> = self.pending.iter().copied().collect();
                // dispatches = who recomputes on this broadcast: the
                // included *live* nodes — and nobody after the last round
                let dispatches = if last {
                    Vec::new()
                } else {
                    self.pending.iter().copied().filter(|i| self.live[*i]).collect()
                };
                tl.push_round(clock.elapsed_secs(), arrivals, dispatches);
            }
            self.ep.broadcast(&ServerToNode::Consensus {
                iter: r as u64,
                included,
                dz_wire: cz.wire,
                last,
            })?;
            self.zhat.as_mut().unwrap().commit(&dz_deq);

            let batch_size = self.pending.len();
            for i in 0..self.n {
                if self.pending.contains(&i) {
                    self.d[i] = 0;
                } else {
                    self.d[i] += 1;
                }
            }
            self.pending.clear();

            if (r + 1) % self.eval_every == 0 {
                let xs =
                    Arena::from_rows_iter(self.m, self.xhat.iter().map(|t| t.estimate()));
                let us =
                    Arena::from_rows_iter(self.m, self.uhat.iter().map(|t| t.estimate()));
                let metrics = self.problem.lock().unwrap().evaluate(&xs, &us, &z)?;
                let comm_bits =
                    self.accounting.lock().unwrap().normalized_bits(self.m);
                recorder.push(IterRecord {
                    iter: r + 1,
                    comm_bits,
                    accuracy: metrics.accuracy,
                    test_acc: metrics.test_acc,
                    loss: metrics.loss,
                    active_nodes: batch_size,
                    wall_s: clock.elapsed_secs(),
                });
            }
        }

        // Drain-then-close: the final broadcast carried `last`, so every
        // live node applies it, acks, and exits. Waiting for the acks (and
        // swallowing any update/skip that raced the last fire — charged on
        // send, never folded) closes the uplink-accounting race exactly;
        // the old Shutdown-broadcast + 100 ms sleepy drain only bounded it.
        let mut waiting: BTreeSet<usize> =
            (0..self.n).filter(|i| self.live[*i]).collect();
        while !waiting.is_empty() {
            match self.ep.recv_timeout(self.stall_timeout)? {
                Some(NodeToServer::ShutdownAck { node }) => {
                    waiting.remove(&node);
                }
                Some(NodeToServer::Leave { node }) => {
                    self.live[node] = false;
                    waiting.remove(&node);
                }
                Some(_) => {}
                None => anyhow::bail!(
                    "shutdown drain stalled: no ack from nodes {waiting:?}"
                ),
            }
        }
        Ok(ServerRunOutput {
            recorder,
            round_arrivals: self.round_arrivals,
            timeline: self.record,
        })
    }

    /// Wait until ≥ P arrivals and every τ−1-stale node has reported.
    /// Both rules range over the **live** set only: a departed node is
    /// neither waited for (its staleness can never clear) nor counted
    /// against P (P shrinks to the surviving population, Zhou & Li's
    /// partial-participation server in the extreme). If everyone leaves,
    /// whatever already arrived fires one final round; an empty house with
    /// an empty batch is a wedge and errors out rather than spinning.
    fn gather_batch(&mut self) -> anyhow::Result<()> {
        loop {
            let live_count = self.live.iter().filter(|l| **l).count();
            let stale_ok = (0..self.n)
                .filter(|i| self.live[*i] && self.d[*i] >= self.tau - 1)
                .all(|i| self.pending.contains(&i));
            let p_eff = self.p_min.min(live_count.max(1));
            if !self.pending.is_empty() && self.pending.len() >= p_eff && stale_ok {
                return Ok(());
            }
            if live_count == 0 {
                if !self.pending.is_empty() {
                    return Ok(());
                }
                anyhow::bail!("all nodes left the deployment; no arrivals to fire");
            }
            match self.ep.recv_timeout(self.stall_timeout)? {
                Some(NodeToServer::Update { node, dx_wire, du_wire, .. }) => {
                    let (cx, cu) = Self::check_frames(dx_wire, du_wire, self.m)?;
                    match &mut self.tier {
                        None => {
                            // O(k)/O(m) frame fold keeps s = Σ(x̂+û)
                            // current without the per-round bank sweep
                            self.fold_update(node, &cx, &cu)?;
                        }
                        Some(t) => {
                            self.xhat[node].commit_frame(&cx)?;
                            self.uhat[node].commit_frame(&cu)?;
                            // route through the colocated aggregator tier:
                            // fold into the pending partial, then forward
                            // the re-quantized delta on the aggregator's
                            // own link immediately (deployment shape:
                            // arrival order is real time, nothing to batch
                            // a virtual instant against)
                            let g = t.route(node, &mut self.rng_topology);
                            t.deliver(node, &cx, &cu, 0.0)?;
                            // Event-trigger dead-band at the aggregator:
                            // a partial within δ forwards credit only —
                            // the mass stays pending (Kahan-tracked) and
                            // rides with the next over-threshold flush.
                            if self.trigger_delta > 0.0
                                && t.pending_inf_norm(g) <= self.trigger_delta
                            {
                                for (child, _) in t.credit_only_flush(g) {
                                    self.pending.insert(child);
                                }
                            } else {
                                let fw =
                                    t.flush(g, self.compressor.as_ref(), &mut self.rng);
                                self.accounting.lock().unwrap().record_uplink(
                                    self.n + g,
                                    MSG_HEADER_BYTES * 8
                                        + fw.cx.wire_bits()
                                        + fw.cu.wire_bits(),
                                );
                                t.commit(g, &fw.cx, &fw.cu)?;
                                self.acc.fold_frames(&fw.cx, &fw.cu)?;
                                for (child, _) in fw.children {
                                    self.pending.insert(child);
                                }
                            }
                        }
                    }
                }
                Some(NodeToServer::Skip { node, .. }) => {
                    // Dead-banded dispatch: zero bits on the books, but
                    // the arrival still counts toward the P/τ trigger
                    // (resets this node's staleness). No bank commit, no
                    // consensus fold, and no aggregator hop — an empty
                    // report needs no aggregation.
                    self.pending.insert(node);
                }
                // Mid-run InitFull from a *dead* slot is a rejoin handshake;
                // from a live node it is a fault-injected duplicate of the
                // init frame and is ignored (the handshake already
                // completed), exactly as before churn existed.
                Some(NodeToServer::InitFull { node, x0, u0 }) => {
                    if !self.live[node] {
                        self.rejoin(node, &x0, &u0)?;
                    }
                }
                Some(NodeToServer::Leave { node }) => self.evict(node),
                // acks only answer a `last` broadcast; none is in flight
                Some(NodeToServer::ShutdownAck { .. }) => {}
                None => anyhow::bail!(
                    "server stalled: {} arrivals, staleness {:?}, live {:?}",
                    self.pending.len(),
                    self.d,
                    self.live
                ),
            }
        }
    }

    /// Validate a received pair of wire frames (dimension check up front,
    /// so a malformed remote frame is an Err on the server loop, never a
    /// panic inside a bank commit).
    fn check_frames(
        dx_wire: Vec<u8>,
        du_wire: Vec<u8>,
        m: usize,
    ) -> anyhow::Result<(Compressed, Compressed)> {
        let cx = Compressed { wire: dx_wire };
        let cu = Compressed { wire: du_wire };
        anyhow::ensure!(
            cx.frame_dim()? == m && cu.frame_dim()? == m,
            "update frame dimension mismatch (expected {m})"
        );
        Ok((cx, cu))
    }

    /// Commit one star-fan-in update straight from its wire frames:
    /// estimate banks, incremental consensus sum, and the pending
    /// (arrival) set.
    fn fold_update(&mut self, node: usize, cx: &Compressed, cu: &Compressed) -> anyhow::Result<()> {
        self.xhat[node].commit_frame(cx)?;
        self.uhat[node].commit_frame(cu)?;
        self.acc.fold_frames(cx, cu)?;
        self.pending.insert(node);
        Ok(())
    }

    /// Churn eviction: the node stops counting toward P and the τ−1 stale
    /// rule. Its banks keep their last committed state (still part of the
    /// consensus sum — ADMM's memory of a departed participant), and an
    /// update of its that already folded this round stays folded; a frame
    /// that was in flight on the dead connection was simply never received,
    /// so nothing needs un-charging.
    fn evict(&mut self, node: usize) {
        self.live[node] = false;
    }

    /// Rejoin re-handshake: a fresh `InitFull` from a previously-evicted
    /// slot resets the node's banks (fresh bank slot — the old quantized
    /// trajectory is gone), washes the consensus sum, and re-bases the
    /// node's downlink with a unicast `InitZ` carrying the current ẑ
    /// estimate, so subsequent C(Δz) deltas apply against the right base.
    fn rejoin(&mut self, node: usize, x0: &[f64], u0: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.tier.is_none(),
            "churn (rejoin) is only supported under the star fan-in"
        );
        anyhow::ensure!(
            x0.len() == self.m && u0.len() == self.m,
            "rejoin init frame dimension mismatch (expected {})",
            self.m
        );
        self.xhat[node].reset(x0);
        self.uhat[node].reset(u0);
        self.live[node] = true;
        self.d[node] = 0;
        self.pending.remove(&node);
        // bank contents changed out-of-band: rebuild s = Σ(x̂+û)
        self.refresh_sum();
        if let Some(z) = &self.zhat {
            self.ep.send(node, ServerToNode::InitZ { z0: z.estimate().to_vec() })?;
        }
        Ok(())
    }

    /// Replay-mode gather: assemble **exactly** the recorded round's
    /// arrival set. Stashed early arrivals scheduled for this round fold
    /// first (ascending node order); live arrivals fold as they land if
    /// they belong here, otherwise they are held back for the round the
    /// recording assigns them to. The node cadence (compute on inclusion,
    /// one update in flight) guarantees every target update eventually
    /// arrives: a node in round r's recorded set was, by construction,
    /// included in some earlier broadcast it has already seen.
    fn gather_replay(&mut self, r: usize) -> anyhow::Result<()> {
        let target = self.replay.as_ref().expect("replay mode")[r].clone();
        for &node in &target {
            if let Some((cx, cu)) = self.stash.remove(&node) {
                self.fold_update(node, &cx, &cu)?;
            } else if self.skip_stash.remove(&node) {
                self.pending.insert(node);
            }
        }
        while !target.iter().all(|i| self.pending.contains(i)) {
            match self.ep.recv_timeout(self.stall_timeout)? {
                Some(NodeToServer::Update { node, dx_wire, du_wire, .. }) => {
                    let (cx, cu) = Self::check_frames(dx_wire, du_wire, self.m)?;
                    if target.contains(&node) && !self.pending.contains(&node) {
                        self.fold_update(node, &cx, &cu)?;
                    } else {
                        // ahead of its recorded round — hold it back as
                        // wire frames (compressed size, not 2·m floats)
                        self.stash.insert(node, (cx, cu));
                    }
                }
                Some(NodeToServer::Skip { node, .. }) => {
                    // a skip is arrival credit with no payload: fold it
                    // into this round if the recording prescribes it,
                    // otherwise hold it for its recorded round
                    if target.contains(&node) && !self.pending.contains(&node) {
                        self.pending.insert(node);
                    } else {
                        self.skip_stash.insert(node);
                    }
                }
                Some(NodeToServer::InitFull { .. }) => {}
                // replay drives a fixed in-process population: a departure
                // would make the recorded arrival sets unsatisfiable
                Some(NodeToServer::Leave { node }) => {
                    anyhow::bail!("node {node} left during timeline replay")
                }
                Some(NodeToServer::ShutdownAck { .. }) => {}
                None => {
                    let missing: Vec<usize> = target
                        .iter()
                        .copied()
                        .filter(|i| !self.pending.contains(i))
                        .collect();
                    anyhow::bail!(
                        "replay stalled at round {r}: waiting for nodes {missing:?}, \
                         folded {:?}, {} stashed",
                        self.pending,
                        self.stash.len()
                    )
                }
            }
        }
        debug_assert_eq!(
            self.pending.iter().copied().collect::<Vec<_>>(),
            target,
            "replay folded an arrival set the recording did not prescribe"
        );
        Ok(())
    }

    /// z = prox(s/n) from the incremental sum — O(m) per round.
    fn consensus(&mut self) -> anyhow::Result<Vec<f64>> {
        self.problem.lock().unwrap().consensus_from_sum(self.acc.sum(), self.n)
    }

    /// Full rebuild of the sum (init + every-K-rounds drift wash-out):
    /// O(n·m) from the per-node banks under the star, O(A·m) from the ŝ_g
    /// partials under a tier (refreshing from leaf banks would leak
    /// information past the re-quantized aggregator hop).
    fn refresh_sum(&mut self) {
        match &self.tier {
            Some(t) => self.acc.refresh(t.refresh_rows()),
            None => self.acc.refresh(estimate_rows(&self.xhat, &self.uhat)),
        }
    }
}
