//! Latency models for the threaded runtime: per-node compute/transmit
//! delays that reproduce the heterogeneous-network conditions (stragglers)
//! that motivate asynchronous ADMM.

use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyModel {
    /// No injected delay (pure compute speed).
    None,
    /// Fixed delay in seconds.
    Const(f64),
    /// Exponential with the given mean (seconds).
    Exp(f64),
    /// Straggler mixture: fast constant delay w.p. (1−p_slow), slow w.p. p_slow.
    Mixture { fast: f64, slow: f64, p_slow: f64 },
}

impl LatencyModel {
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match *self {
            LatencyModel::None => 0.0,
            LatencyModel::Const(s) => s,
            LatencyModel::Exp(mean) => rng.exponential(mean),
            LatencyModel::Mixture { fast, slow, p_slow } => {
                if rng.bernoulli(p_slow) {
                    slow
                } else {
                    fast
                }
            }
        }
    }

    /// Expected delay (for analytic wall-clock estimates in benches).
    pub fn mean(&self) -> f64 {
        match *self {
            LatencyModel::None => 0.0,
            LatencyModel::Const(s) => s,
            LatencyModel::Exp(mean) => mean,
            LatencyModel::Mixture { fast, slow, p_slow } => {
                fast * (1.0 - p_slow) + slow * p_slow
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_and_none() {
        let mut rng = Pcg64::seed_from_u64(0);
        assert_eq!(LatencyModel::None.sample(&mut rng), 0.0);
        assert_eq!(LatencyModel::Const(0.25).sample(&mut rng), 0.25);
    }

    #[test]
    fn empirical_means_match() {
        let mut rng = Pcg64::seed_from_u64(1);
        for model in [
            LatencyModel::Exp(0.2),
            LatencyModel::Mixture { fast: 0.01, slow: 0.5, p_slow: 0.3 },
        ] {
            let n = 50_000;
            let mean: f64 =
                (0..n).map(|_| model.sample(&mut rng)).sum::<f64>() / n as f64;
            assert!(
                (mean - model.mean()).abs() < 0.01,
                "{model:?}: {mean} vs {}",
                model.mean()
            );
        }
    }

    #[test]
    fn samples_nonnegative() {
        let mut rng = Pcg64::seed_from_u64(2);
        let model = LatencyModel::Exp(0.1);
        for _ in 0..1000 {
            assert!(model.sample(&mut rng) >= 0.0);
        }
    }
}
