//! probe: does buffer caching for constants help on CPU-PJRT?
use qadmm::util::rng::Pcg64;
use std::time::Instant;

fn main() {
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file("artifacts/lasso_node_step.hlo.txt").unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
    let mut rng = Pcg64::seed_from_u64(1);
    let m = 200;
    let minv = rng.normal_vec(m * m, 0.0, 0.01);
    let vecs: Vec<Vec<f64>> = (0..7).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();

    // baseline: literals every call
    let mk_lit = |data: &Vec<f64>, dims: &[i64]| xla::Literal::vec1(data).reshape(dims).unwrap();
    let reps = 200;
    for _ in 0..3 { run_lit(&exe, &minv, &vecs, &mk_lit); }
    let t = Instant::now();
    for _ in 0..reps { run_lit(&exe, &minv, &vecs, &mk_lit); }
    println!("execute with literals: {:.1}µs", t.elapsed().as_secs_f64() / reps as f64 * 1e6);

    // cached const buffers + fresh varying buffers, execute_b
    let minv_buf = client.buffer_from_host_buffer(&minv, &[m, m], None).unwrap();
    let atb2_buf = client.buffer_from_host_buffer(&vecs[0], &[m], None).unwrap();
    let rho = client.buffer_from_host_buffer(&[500.0f64], &[], None);
    let rho = match rho { Ok(b) => b, Err(e) => { println!("scalar buffer err: {e:?}"); return; } };
    let s = client.buffer_from_host_buffer(&[3.0f64], &[], None).unwrap();
    for _ in 0..3 { run_buf(&client, &exe, &minv_buf, &atb2_buf, &vecs, &rho, &s, m); }
    let t = Instant::now();
    for _ in 0..reps { run_buf(&client, &exe, &minv_buf, &atb2_buf, &vecs, &rho, &s, m); }
    println!("execute_b cached consts: {:.1}µs", t.elapsed().as_secs_f64() / reps as f64 * 1e6);
}

fn run_lit(exe: &xla::PjRtLoadedExecutable, minv: &Vec<f64>, vecs: &[Vec<f64>],
           mk: &dyn Fn(&Vec<f64>, &[i64]) -> xla::Literal) {
    let mut args = vec![mk(minv, &[200, 200])];
    for v in &vecs[..7] { args.push(mk(v, &[200])); }
    args.push(xla::Literal::scalar(500.0f64));
    args.push(xla::Literal::scalar(3.0f64));
    let out = exe.execute::<xla::Literal>(&args).unwrap()[0][0].to_literal_sync().unwrap();
    std::hint::black_box(out);
}

fn run_buf(client: &xla::PjRtClient, exe: &xla::PjRtLoadedExecutable,
           minv: &xla::PjRtBuffer, atb2: &xla::PjRtBuffer, vecs: &[Vec<f64>],
           rho: &xla::PjRtBuffer, s: &xla::PjRtBuffer, m: usize) {
    let varying: Vec<xla::PjRtBuffer> = vecs[1..7]
        .iter()
        .map(|v| client.buffer_from_host_buffer(v, &[m], None).unwrap())
        .collect();
    let mut args: Vec<&xla::PjRtBuffer> = vec![minv, atb2];
    for v in &varying { args.push(v); }
    args.push(rho);
    args.push(s);
    let out = exe.execute_b(&args).unwrap()[0][0].to_literal_sync().unwrap();
    std::hint::black_box(out);
}
