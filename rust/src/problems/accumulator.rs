//! Incremental server consensus state: the running sum s = Σᵢ(x̂ᵢ + ûᵢ).
//!
//! The paper's server (Algorithm 1 lines 27–43) recomputes the consensus
//! input v = mean(x̂ + û) from every node's estimate bank on every round,
//! an O(n·m) sweep even though only P ≤ n nodes arrived. But the banks
//! evolve *only* by dequantized deltas: `MsgArrive` commits x̂ᵢ += C(Δxᵢ),
//! ûᵢ += C(Δuᵢ) and nothing else ever touches them. So the server can
//! carry s across rounds and fold each arrival in as
//!
//! ```text
//!     s ← s + C(Δxᵢ) + C(Δuᵢ)          (O(nnz) per arrival)
//! ```
//!
//! after which one fire is `z = prox(s/n)` — O(m) total via
//! [`crate::problems::Problem::consensus_from_sum`] — instead of O(n·m).
//! At n = 1024, m = 10240 that turns a ~160 MB bank sweep per round into a
//! few hundred KB of arrival folds.
//!
//! # Floating-point drift and the two defenses
//!
//! The incremental s is *not* bitwise the recomputed Σ(x̂ᵢ + ûᵢ): addition
//! is non-associative, and after many folds the rounding errors of the two
//! evaluation orders diverge. Two mechanisms keep the gap far below the
//! quantization noise the algorithm already tolerates:
//!
//! * **Kahan compensation on every fold** ([`ConsensusAccumulator::fold`]):
//!   each coordinate keeps a running compensation term, so the error of the
//!   incremental sum stays O(ε)·Σ|δ| instead of growing with the number of
//!   folds. The property suite (`tests/prop.rs`) drives 10k folds without
//!   refresh and bounds the gap at ≤ 1e-10 relative.
//! * **Periodic full recompute** ([`ConsensusAccumulator::refresh`], every
//!   `refresh_every` rounds, default on — see
//!   [`crate::config::ExperimentConfig::consensus_refresh_every`]): the sum
//!   and its compensation are rebuilt from the banks in node order, washing
//!   out whatever drift accumulated. This is the only remaining O(n·m)
//!   server work, amortized to O(n·m / K) per round; `refresh_every = 0`
//!   disables it entirely (the Kahan bound still holds).
//!
//! # The zero-skip invariant
//!
//! [`KahanVec::kahan_add`] is a no-op when the addend is ±0.0. Raw Kahan
//! is *not*: with a nonzero compensation term, adding 0.0 absorbs
//! `−comp` into the sum and changes both words. The skip is what makes a
//! sparse fold over a wire frame's stored entries — O(k) for top-k /
//! rand-k ([`crate::compress::Compressed::fold_into`]) — bitwise
//! identical to materializing the dense vector and folding all m
//! coordinates: the m − k absent coordinates dequantize to exactly 0.0,
//! and 0.0-adds now touch nothing on the dense path either. (`-0.0 ==
//! 0.0` is true, so negative zero also skips, which additionally avoids
//! the `-0.0 + 0.0 = +0.0` sign flip.) Every fold in the repo goes
//! through this one function, so the invariant holds uniformly across
//! engines and the parity contract is unaffected.
//!
//! # Blocked layout and coordinate sharding
//!
//! The fold kernels walk fixed-size [`BLOCK`]-coordinate blocks with a
//! branchless select instead of the early return (bit-identical results),
//! so the inner loop has a fixed trip count and no cross-lane dependency —
//! the shape LLVM autovectorizes. Because the Kahan state is
//! per-coordinate, the m dimension also shards deterministically:
//! [`KahanVec::fold2_sharded`] fans disjoint coordinate ranges across
//! scoped worker threads and every coordinate sees exactly the op sequence
//! of the serial fold, so any shard count produces the same bits
//! (`tests/prop.rs` pins shards ∈ {1, 3, 8} against serial).
//!
//! # Determinism contract
//!
//! The sequential simulator and the event engine share this type and fold
//! in the same order at zero latency (ascending node id within a virtual
//! instant), so the `tests/engine_parity.rs` bit-identity contract holds
//! through the incremental path: same folds, same refresh rounds, same
//! bits. The threaded coordinator folds in real arrival order — no bitwise
//! claim there, only the ≤1e-10 drift bound.

use crate::compress::Compressed;
use crate::snapshot::codec::{Pack, Reader, Writer};

/// Coordinate-block width of the fold kernels: long enough to fill SIMD
/// lanes with room for unrolling, short enough that the scalar remainder
/// (< BLOCK coordinates) never matters.
pub const BLOCK: usize = 64;

/// Dimension below which [`auto_shards`] stays serial: a scoped-thread
/// fan-out costs a few hundred µs of spawn/join, which only pays for
/// itself once the per-shard fold is comparably large. Purely a
/// performance knob — sharding never changes bits (see module docs).
const SHARD_MIN_DIM: usize = 1 << 16;

/// Shard count for a fold/refresh over `m` coordinates: 1 below the
/// crossover, else the machine's parallelism (capped — beyond a handful
/// of shards the fold is memory-bound).
pub fn auto_shards(m: usize) -> usize {
    if m < SHARD_MIN_DIM {
        return 1;
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).clamp(1, 16)
}

/// A Kahan-compensated running vector sum: the *mergeable partial sum*
/// primitive shared by the server's [`ConsensusAccumulator`] and the
/// per-aggregator pending buffers of hierarchical fan-in topologies
/// ([`crate::topology::AggregatorTier`]). Each coordinate carries its
/// compensation term, so the represented value stays within O(ε)·Σ|δ| of
/// the exact sum regardless of fold count, and two independently
/// accumulated partials can be [`KahanVec::merge`]d without losing either
/// side's low-order bits.
#[derive(Clone, Debug)]
pub struct KahanVec {
    sum: Vec<f64>,
    /// Per-coordinate compensation: the low-order error the last addition
    /// *included* (subtracted from the next addend).
    comp: Vec<f64>,
}

/// One Kahan update as a pure step: the branchless form of
/// [`KahanVec::kahan_add`] (a select instead of an early return —
/// bit-identical results, including the ±0.0 skip), so the fixed-trip
/// block loops stay free of per-lane control flow and vectorize.
#[inline(always)]
fn kahan_step(s: f64, c: f64, v: f64) -> (f64, f64) {
    let y = v - c;
    let t = s + y;
    let nc = (t - s) - y;
    let skip = v == 0.0;
    (if skip { s } else { t }, if skip { c } else { nc })
}

/// Blocked single-addend fold kernel over a coordinate range (`negate`
/// folds −v, the error-feedback shape).
fn fold1_range(sum: &mut [f64], comp: &mut [f64], v: &[f64], negate: bool) {
    debug_assert!(sum.len() == comp.len() && sum.len() == v.len());
    let head = sum.len() - sum.len() % BLOCK;
    let mut off = 0;
    while off < head {
        let s: &mut [f64; BLOCK] = (&mut sum[off..off + BLOCK]).try_into().unwrap();
        let c: &mut [f64; BLOCK] = (&mut comp[off..off + BLOCK]).try_into().unwrap();
        let x: &[f64; BLOCK] = v[off..off + BLOCK].try_into().unwrap();
        for j in 0..BLOCK {
            let xj = if negate { -x[j] } else { x[j] };
            let (ns, nc) = kahan_step(s[j], c[j], xj);
            s[j] = ns;
            c[j] = nc;
        }
        off += BLOCK;
    }
    for j in head..sum.len() {
        let xj = if negate { -v[j] } else { v[j] };
        KahanVec::kahan_add(&mut sum[j], &mut comp[j], xj);
    }
}

/// Blocked paired fold kernel (s += a + b) over a coordinate range.
fn fold2_range(sum: &mut [f64], comp: &mut [f64], a: &[f64], b: &[f64]) {
    debug_assert!(sum.len() == comp.len() && sum.len() == a.len() && sum.len() == b.len());
    let head = sum.len() - sum.len() % BLOCK;
    let mut off = 0;
    while off < head {
        let s: &mut [f64; BLOCK] = (&mut sum[off..off + BLOCK]).try_into().unwrap();
        let c: &mut [f64; BLOCK] = (&mut comp[off..off + BLOCK]).try_into().unwrap();
        let av: &[f64; BLOCK] = a[off..off + BLOCK].try_into().unwrap();
        let bv: &[f64; BLOCK] = b[off..off + BLOCK].try_into().unwrap();
        for j in 0..BLOCK {
            let (s1, c1) = kahan_step(s[j], c[j], av[j]);
            let (s2, c2) = kahan_step(s1, c1, bv[j]);
            s[j] = s2;
            c[j] = c2;
        }
        off += BLOCK;
    }
    for j in head..sum.len() {
        KahanVec::kahan_add(&mut sum[j], &mut comp[j], a[j]);
        KahanVec::kahan_add(&mut sum[j], &mut comp[j], b[j]);
    }
}

impl KahanVec {
    pub fn zeros(m: usize) -> Self {
        Self { sum: vec![0.0; m], comp: vec![0.0; m] }
    }

    pub fn dim(&self) -> usize {
        self.sum.len()
    }

    /// The represented value (the compensated running sum).
    pub fn value(&self) -> &[f64] {
        &self.sum
    }

    /// One compensated scalar addition. ±0.0 addends are skipped — the
    /// invariant that makes sparse frame folds bitwise identical to dense
    /// folds of the materialized vector (see module docs); every fold in
    /// the repo reaches this semantic, scalar or blocked.
    #[inline]
    pub fn kahan_add(sum: &mut f64, comp: &mut f64, v: f64) {
        if v == 0.0 {
            return;
        }
        let y = v - *comp;
        let t = *sum + y;
        *comp = (t - *sum) - y;
        *sum = t;
    }

    /// Fold a single coordinate: the sink of the wire-frame entry visitors
    /// ([`crate::compress::Compressed::fold_into`]).
    #[inline]
    pub fn fold_at(&mut self, j: usize, v: f64) {
        Self::kahan_add(&mut self.sum[j], &mut self.comp[j], v);
    }

    /// s += v, compensated per coordinate.
    pub fn add(&mut self, v: &[f64]) {
        debug_assert_eq!(v.len(), self.sum.len());
        fold1_range(&mut self.sum, &mut self.comp, v, false);
    }

    /// s −= v (error-feedback residual after a compressed forward).
    pub fn sub(&mut self, v: &[f64]) {
        debug_assert_eq!(v.len(), self.sum.len());
        fold1_range(&mut self.sum, &mut self.comp, v, true);
    }

    /// Paired fold s += a + b in one pass (the consensus arrival shape).
    pub fn fold2(&mut self, a: &[f64], b: &[f64]) {
        debug_assert_eq!(a.len(), self.sum.len());
        debug_assert_eq!(b.len(), self.sum.len());
        fold2_range(&mut self.sum, &mut self.comp, a, b);
    }

    /// [`Self::fold2`] with the coordinate range fanned across `shards`
    /// scoped worker threads. Deterministic by construction: the Kahan
    /// state is per-coordinate and the shards are disjoint contiguous
    /// ranges, so coordinate j undergoes exactly the serial op sequence no
    /// matter which thread owns it — any shard count yields the same bits
    /// (`tests/prop.rs`). `shards <= 1` (or a tiny m) runs serial with no
    /// spawn.
    pub fn fold2_sharded(&mut self, a: &[f64], b: &[f64], shards: usize) {
        debug_assert_eq!(a.len(), self.sum.len());
        debug_assert_eq!(b.len(), self.sum.len());
        let m = self.sum.len();
        let shards = shards.clamp(1, m.max(1));
        if shards <= 1 {
            return self.fold2(a, b);
        }
        let chunk = m.div_ceil(shards);
        std::thread::scope(|scope| {
            let mut sum_rest: &mut [f64] = &mut self.sum;
            let mut comp_rest: &mut [f64] = &mut self.comp;
            let mut a_rest = a;
            let mut b_rest = b;
            while !sum_rest.is_empty() {
                let take = chunk.min(sum_rest.len());
                let (s0, s1) = sum_rest.split_at_mut(take);
                let (c0, c1) = comp_rest.split_at_mut(take);
                let (a0, a1) = a_rest.split_at(take);
                let (b0, b1) = b_rest.split_at(take);
                scope.spawn(move || fold2_range(s0, c0, a0, b0));
                sum_rest = s1;
                comp_rest = c1;
                a_rest = a1;
                b_rest = b1;
            }
        });
    }

    /// Fold every `(a, b)` row pair, sharded over the coordinate range:
    /// the refresh shape (n rows × m coordinates with the row loop inside
    /// each shard, so per-coordinate row order — hence bits — matches the
    /// serial row-by-row fold exactly).
    pub fn fold2_rows_sharded(&mut self, rows: &[(&[f64], &[f64])], shards: usize) {
        let m = self.sum.len();
        let shards = shards.clamp(1, m.max(1));
        if shards <= 1 {
            for (a, b) in rows {
                self.fold2(a, b);
            }
            return;
        }
        let chunk = m.div_ceil(shards);
        std::thread::scope(|scope| {
            let mut sum_rest: &mut [f64] = &mut self.sum;
            let mut comp_rest: &mut [f64] = &mut self.comp;
            let mut off = 0usize;
            while !sum_rest.is_empty() {
                let take = chunk.min(sum_rest.len());
                let (s0, s1) = sum_rest.split_at_mut(take);
                let (c0, c1) = comp_rest.split_at_mut(take);
                let range = off..off + take;
                scope.spawn(move || {
                    for (a, b) in rows {
                        fold2_range(s0, c0, &a[range.clone()], &b[range.clone()]);
                    }
                });
                sum_rest = s1;
                comp_rest = c1;
                off += take;
            }
        });
    }

    /// Fold another partial sum in, preserving its compensation: the true
    /// value of `other` is `sum − comp` to working precision, so the merge
    /// adds `other.sum` and then corrects by `−other.comp`. No runtime
    /// path calls this yet — it is the composition primitive for
    /// multi-level aggregator trees (aggregators of aggregators merge
    /// their children's partials; see the ROADMAP topology follow-up) and
    /// is kept pinned by its unit test until that tier lands.
    pub fn merge(&mut self, other: &KahanVec) {
        debug_assert_eq!(other.dim(), self.dim());
        for (j, (s, c)) in self.sum.iter_mut().zip(self.comp.iter_mut()).enumerate() {
            Self::kahan_add(s, c, other.sum[j]);
            Self::kahan_add(s, c, -other.comp[j]);
        }
    }

    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|v| *v = 0.0);
        self.comp.iter_mut().for_each(|v| *v = 0.0);
    }
}

/// Running Kahan-compensated Σᵢ(x̂ᵢ + ûᵢ) with a periodic full-recompute
/// refresh. See the module docs for fold/finalize/refresh semantics.
#[derive(Clone, Debug)]
pub struct ConsensusAccumulator {
    /// s = Σᵢ(x̂ᵢ + ûᵢ) with per-coordinate compensation.
    state: KahanVec,
    /// Full recompute cadence in consensus rounds (0 = never).
    refresh_every: usize,
}

impl ConsensusAccumulator {
    pub fn new(m: usize, refresh_every: usize) -> Self {
        Self { state: KahanVec::zeros(m), refresh_every }
    }

    pub fn dim(&self) -> usize {
        self.state.dim()
    }

    /// The current running sum s (pass to
    /// [`crate::problems::Problem::consensus_from_sum`]).
    pub fn sum(&self) -> &[f64] {
        self.state.value()
    }

    /// Fold one arrival's dequantized deltas: s += C(Δx) + C(Δu).
    /// Must be called with exactly the vectors committed into the estimate
    /// banks so that s keeps tracking Σᵢ(x̂ᵢ + ûᵢ). Large-m folds shard
    /// across the worker pool ([`auto_shards`]) — bit-identical to serial.
    pub fn fold(&mut self, dx: &[f64], du: &[f64]) {
        let shards = auto_shards(self.state.dim());
        self.state.fold2_sharded(dx, du, shards);
    }

    /// Fold one arrival straight from its wire frames: s += C(Δx) + C(Δu)
    /// without materializing either vector — O(k) for sparse frames. The
    /// zero-skip invariant makes this bitwise identical to
    /// [`Self::fold`] on the decoded vectors, and folding the x frame
    /// fully before the u frame matches the interleaved per-coordinate
    /// `fold2` order exactly because each coordinate's Kahan state is
    /// touched at most once per frame (`tests/prop.rs` pins it).
    pub fn fold_frames(&mut self, cx: &Compressed, cu: &Compressed) -> anyhow::Result<()> {
        cx.fold_into(&mut self.state)?;
        cu.fold_into(&mut self.state)
    }

    /// True when the round about to fire (1-based) is a refresh round. Both
    /// in-process engines call this with their shared round counter, so at
    /// parity they refresh on identical rounds.
    pub fn refresh_due(&self, round: usize) -> bool {
        self.refresh_every > 0 && round % self.refresh_every == 0
    }

    /// Streaming refresh, step 1: reset the sum and compensation. Pair
    /// with [`Self::refresh_fold_row`] per node. This is the serial row
    /// order of [`Self::refresh`] — which sharding is property-pinned
    /// bitwise-equal to — so a streaming caller that can only materialize
    /// one bank row at a time (quantized-at-rest banks at n = 10^6)
    /// produces the identical sum.
    pub fn refresh_begin(&mut self) {
        self.state.reset();
    }

    /// Streaming refresh, step 2: fold one node's (x̂ᵢ, ûᵢ) pair, in node
    /// order, after [`Self::refresh_begin`].
    pub fn refresh_fold_row(&mut self, x: &[f64], u: &[f64]) {
        self.state.fold2(x, u);
    }

    /// Full recompute from the estimate banks, in iteration order, resetting
    /// the compensation: the O(n·m) drift wash-out. `rows` yields each
    /// node's (x̂ᵢ, ûᵢ) estimate slices. Large-m refreshes shard the
    /// coordinate range across the worker pool — bit-identical to serial.
    pub fn refresh<'b>(&mut self, rows: impl Iterator<Item = (&'b [f64], &'b [f64])>) {
        self.state.reset();
        let shards = auto_shards(self.state.dim());
        if shards <= 1 {
            for (x, u) in rows {
                self.state.fold2(x, u);
            }
        } else {
            let rows: Vec<(&[f64], &[f64])> = rows.collect();
            self.state.fold2_rows_sharded(&rows, shards);
        }
    }
}

impl Pack for KahanVec {
    fn pack(&self, w: &mut Writer) {
        self.sum.pack(w);
        self.comp.pack(w);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        let sum = Vec::<f64>::unpack(r)?;
        let comp = Vec::<f64>::unpack(r)?;
        anyhow::ensure!(
            sum.len() == comp.len(),
            "snapshot kahan vec: sum/compensation length mismatch"
        );
        Ok(Self { sum, comp })
    }
}

/// The compensation terms travel with the sum: restoring only `value()`
/// would discard the low-order bits and break the bit-identity contract on
/// the very next fold.
impl Pack for ConsensusAccumulator {
    fn pack(&self, w: &mut Writer) {
        self.state.pack(w);
        w.put_usize(self.refresh_every);
    }
    fn unpack(r: &mut Reader<'_>) -> anyhow::Result<Self> {
        Ok(Self { state: KahanVec::unpack(r)?, refresh_every: r.get_usize()? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn fold_tracks_plain_sum_on_small_inputs() {
        let mut acc = ConsensusAccumulator::new(3, 0);
        acc.fold(&[1.0, 2.0, 3.0], &[0.5, 0.5, 0.5]);
        acc.fold(&[-1.0, 0.0, 1.0], &[0.0, 0.0, 0.0]);
        assert_eq!(acc.sum(), &[0.5, 2.5, 4.5]);
    }

    #[test]
    fn refresh_matches_direct_fold_from_zero() {
        let mut rng = Pcg64::seed_from_u64(3);
        let m = 17;
        let xs: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();
        let us: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(m, 0.0, 1.0)).collect();
        let mut a = ConsensusAccumulator::new(m, 4);
        a.refresh(xs.iter().zip(&us).map(|(x, u)| (x.as_slice(), u.as_slice())));
        let mut b = ConsensusAccumulator::new(m, 4);
        for (x, u) in xs.iter().zip(&us) {
            b.fold(x, u);
        }
        assert_eq!(a.sum(), b.sum());
    }

    #[test]
    fn refresh_cadence() {
        let acc = ConsensusAccumulator::new(1, 5);
        assert!(!acc.refresh_due(1));
        assert!(!acc.refresh_due(4));
        assert!(acc.refresh_due(5));
        assert!(acc.refresh_due(10));
        let never = ConsensusAccumulator::new(1, 0);
        for r in 1..100 {
            assert!(!never.refresh_due(r));
        }
    }

    /// A single `add` from zero is exact (the compensation starts at 0 and
    /// the addend lands unrounded): this is what keeps the degenerate
    /// one-child-per-aggregator tree bit-identical to the star fan-in.
    #[test]
    fn kahan_vec_single_add_from_zero_is_exact() {
        let mut rng = Pcg64::seed_from_u64(17);
        let v = rng.normal_vec(33, 0.0, 3.0);
        let mut k = KahanVec::zeros(33);
        k.add(&v);
        assert_eq!(k.value(), v.as_slice());
        // and subtracting it back lands exactly on zero
        k.sub(&v);
        assert!(k.value().iter().all(|&x| x == 0.0));
    }

    /// The zero-skip invariant: adding a zero vector changes nothing —
    /// bitwise — even with nonzero compensation in flight. (Raw Kahan
    /// would absorb −comp into the sum here; the skip is what makes
    /// sparse frame folds ≡ dense folds, see module docs.)
    #[test]
    fn zero_addend_is_bitwise_noop_even_with_pending_compensation() {
        let mut k = KahanVec::zeros(4);
        // build nonzero compensation: big + tiny leaves comp != 0
        k.add(&[1e16, 1.0, -1e16, 3.5]);
        k.add(&[1.0, 1e-16, 1.0, 1e16]);
        let before: Vec<u64> = {
            let mut w = Writer::new();
            k.pack(&mut w);
            w.into_inner().iter().map(|&b| b as u64).collect()
        };
        k.add(&[0.0, -0.0, 0.0, -0.0]);
        k.fold2(&[0.0; 4], &[-0.0, 0.0, -0.0, 0.0]);
        k.sub(&[0.0, 0.0, -0.0, -0.0]);
        let after: Vec<u64> = {
            let mut w = Writer::new();
            k.pack(&mut w);
            w.into_inner().iter().map(|&b| b as u64).collect()
        };
        assert_eq!(before, after, "±0.0 addends must not touch sum or comp");
    }

    /// The blocked kernels agree bitwise with the scalar `kahan_add` path
    /// across sizes straddling the BLOCK boundary (remainder handling).
    #[test]
    fn blocked_fold_matches_scalar_fold_bitwise() {
        let mut rng = Pcg64::seed_from_u64(41);
        for m in [1, BLOCK - 1, BLOCK, BLOCK + 1, 3 * BLOCK + 7] {
            let a = rng.normal_vec(m, 0.0, 1e8);
            let b = rng.normal_vec(m, 0.0, 1e-8);
            let mut blocked = KahanVec::zeros(m);
            blocked.fold2(&a, &b);
            blocked.fold2(&b, &a);
            let mut scalar = KahanVec::zeros(m);
            for j in 0..m {
                scalar.fold_at(j, a[j]);
                scalar.fold_at(j, b[j]);
            }
            for j in 0..m {
                scalar.fold_at(j, b[j]);
                scalar.fold_at(j, a[j]);
            }
            let bits = |k: &KahanVec| -> Vec<u64> { k.value().iter().map(|v| v.to_bits()).collect() };
            assert_eq!(bits(&blocked), bits(&scalar), "m={m}");
        }
    }

    /// Sharded folds are bit-identical to serial for every shard count
    /// (per-coordinate Kahan state ⇒ m-sharding cannot reorder any
    /// coordinate's op sequence).
    #[test]
    fn sharded_fold_bitwise_identical_to_serial() {
        let mut rng = Pcg64::seed_from_u64(43);
        let m = 5 * BLOCK + 13;
        let a = rng.normal_vec(m, 0.0, 1e6);
        let b = rng.normal_vec(m, 0.0, 1e-6);
        let mut serial = KahanVec::zeros(m);
        serial.fold2(&a, &b);
        serial.fold2(&b, &a);
        for shards in [1usize, 2, 3, 8, 64] {
            let mut sharded = KahanVec::zeros(m);
            sharded.fold2_sharded(&a, &b, shards);
            sharded.fold2_sharded(&b, &a, shards);
            let pack = |k: &KahanVec| {
                let mut w = Writer::new();
                k.pack(&mut w);
                w.into_inner()
            };
            assert_eq!(pack(&serial), pack(&sharded), "shards={shards}");
        }
        // row-sharded refresh shape
        let rows: Vec<(&[f64], &[f64])> = vec![(&a, &b), (&b, &a), (&a, &a)];
        let mut serial_rows = KahanVec::zeros(m);
        for (x, u) in &rows {
            serial_rows.fold2(x, u);
        }
        for shards in [1usize, 3, 8] {
            let mut sharded = KahanVec::zeros(m);
            sharded.fold2_rows_sharded(&rows, shards);
            assert_eq!(
                serial_rows.value().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                sharded.value().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "rows shards={shards}"
            );
        }
    }

    /// Merging two independently accumulated partials matches folding both
    /// streams into one accumulator, to working precision.
    #[test]
    fn kahan_vec_merge_matches_joint_fold() {
        let mut rng = Pcg64::seed_from_u64(23);
        let m = 16;
        let a_stream: Vec<Vec<f64>> = (0..500).map(|_| rng.normal_vec(m, 0.0, 1e6)).collect();
        let b_stream: Vec<Vec<f64>> = (0..500).map(|_| rng.normal_vec(m, 0.0, 1e-6)).collect();
        let mut a = KahanVec::zeros(m);
        let mut b = KahanVec::zeros(m);
        let mut joint = KahanVec::zeros(m);
        for (va, vb) in a_stream.iter().zip(&b_stream) {
            a.add(va);
            b.add(vb);
            joint.add(va);
            joint.add(vb);
        }
        a.merge(&b);
        let norm = joint.value().iter().fold(1.0f64, |mx, v| mx.max(v.abs()));
        for (x, y) in a.value().iter().zip(joint.value()) {
            assert!((x - y).abs() <= 1e-12 * norm, "merge {x} vs joint {y}");
        }
    }

    /// Kahan beats naive summation on an adversarial magnitude mix.
    #[test]
    fn kahan_compensates_magnitude_spread() {
        let m = 1;
        let mut acc = ConsensusAccumulator::new(m, 0);
        let mut naive = 0.0f64;
        let big = 1e14;
        acc.fold(&[big], &[0.0]);
        naive += big;
        for _ in 0..10_000 {
            acc.fold(&[0.1], &[0.0]);
            naive += 0.1;
        }
        acc.fold(&[-big], &[0.0]);
        naive += -big;
        let exact = 1000.0;
        let kahan_err = (acc.sum()[0] - exact).abs();
        let naive_err = (naive - exact).abs();
        assert!(kahan_err <= 1e-9, "kahan err {kahan_err}");
        assert!(naive_err > kahan_err, "naive {naive_err} vs kahan {kahan_err}");
    }
}
